//! `repro` — the launcher.
//!
//! ```text
//! repro train --config moe-32 --steps 500 [--checkpoint out.ckpt]
//! repro train-native --devices 2 --steps 40     (artifact-free)
//! repro eval  --config moe-32 --checkpoint out.ckpt
//! repro distributed --config moe-32 --devices 8 --steps 20
//! repro table1|table6|table7|table8|table9|fig2|fig4|mt|mt5  [--steps N]
//! repro efficiency --devices 16
//! repro cluster --rows 8 [--seed S]
//! repro chaos --rows 8 [--seed S]
//! repro serve --devices 4 --requests 400
//! repro tenants --devices 2 --victims 16
//! repro trace --out trace.json
//! repro info
//! ```
//!
//! (clap is not in the offline vendored crate set; flags are parsed by the
//! tiny [`Args`] helper below with the same `--flag value` conventions.)

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use moe::harness::experiments::{run_lm_experiment, ExperimentOpts};
use moe::harness::tables;
use moe::runtime::{Engine, Manifest};

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument '{a}' (flags are --name value)");
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        self.get(name, &default.to_string())
            .parse()
            .with_context(|| format!("--{name} must be an integer"))
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [flags]\n\
         commands:\n\
           train        --config NAME --steps N [--checkpoint PATH] [--devices D]\n\
           train-native [--devices D] [--steps N]   (no artifacts: streamed\n\
                        engine + native gating backward, balance-CV trajectory)\n\
           eval         --config NAME --checkpoint PATH\n\
           distributed  --config NAME [--devices D] [--steps N]\n\
           table1 | table6 | table7 | table8 | table9   [--steps N]\n\
           fig2 [--side left|right] | fig4              [--steps N]\n\
           mt | mt5                                     [--steps N]\n\
           efficiency   [--devices D] [--tokens N]\n\
           cluster      [--rows R] [--seed S]   (64..4096-expert scaling\n\
                        study: real engine, corrected \u{a7}3.2 traffic, GShard\n\
                        capacity sweep on the multi-host topology model)\n\
           chaos        [--rows R] [--seed S]   (deterministic fault\n\
                        injection sweep: rates x recovery policies + shard\n\
                        deaths, proving liveness and conservation)\n\
           serve        [--devices D] [--requests N] [--seed S]\n\
           tenants      [--devices D] [--victims N] [--seed S]   (multi-tenant\n\
                        fairness sweep: one heavy hitter vs one SLO victim,\n\
                        weighted-fair vs global-FIFO drains vs victim-solo\n\
                        baseline, per-tenant ledgers + isolation verdict)\n\
           trace        [--devices D] [--tokens N] [--requests N] [--seed S]\n\
                        [--out PATH]   (one traced streamed step + one traced\n\
                        serve burst -> Chrome trace JSON for Perfetto, plus\n\
                        the registry snapshot as JSON and Prometheus text)\n\
           info\n\
         common flags: --artifacts DIR (default: artifacts)"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else { usage() };
    let args = Args::parse(&argv[1..])?;
    let artifacts = args.get("artifacts", "artifacts");
    let steps = args.get_u64("steps", 200)?;

    match cmd.as_str() {
        "train" => {
            let cfg = args.get("config", "moe-32");
            let engine = Engine::new()?;
            let manifest = Manifest::load(&artifacts)?;
            let ckpt = args.flags.get("checkpoint").map(std::path::PathBuf::from);
            let opts = ExperimentOpts {
                steps,
                devices: args.get_u64("devices", 16)? as usize,
                log_every: args.get_u64("log-every", 20)?,
                checkpoint: ckpt,
                ..Default::default()
            };
            let r = run_lm_experiment(&engine, &manifest, &cfg, &opts)?;
            println!(
                "config={} steps={} test_ppl={:.3} ops/ts={} tflops/dev={:.2} \
                 wall={:.1}s",
                r.config, r.steps, r.test_perplexity, r.ops_per_timestep,
                r.tflops_per_device, r.wall_secs
            );
        }
        "train-native" => {
            // artifact-free: the streamed executor + the exact native
            // backward through the gating network (eq-6/eq-8 balance
            // losses, Adam), printing the balance-CV trajectory next
            // to a frozen-gating baseline
            let devices = args.get_u64("devices", 2)? as usize;
            let steps = args.get_u64("steps", 40)? as usize;
            moe::harness::distributed::native_training_demo(devices, steps)?;
        }
        "eval" => {
            let cfg = args.get("config", "moe-32");
            let ckpt = args
                .flags
                .get("checkpoint")
                .context("--checkpoint required")?;
            let engine = Engine::new()?;
            let manifest = Manifest::load(&artifacts)?;
            let trainer = moe::train::Trainer::new(&engine, &manifest, &cfg)?;
            let state = moe::train::checkpoint::load(
                std::path::Path::new(ckpt),
                &cfg,
            )?;
            let c = &trainer.entry.config;
            let corpus = moe::data::synthetic::TopicCorpus::new(
                moe::data::synthetic::CorpusSpec {
                    vocab: c.vocab,
                    ..Default::default()
                },
            );
            let mut b = moe::data::Batcher::new(&corpus, c.batch, c.seq_len,
                                                1 << 32);
            let e = trainer.evaluate(&state, &mut b, 50)?;
            println!("config={cfg} step={} test_ppl={:.3}", state.step,
                     e.perplexity());
        }
        "distributed" => {
            let cfg = args.get("config", "moe-32");
            let devices = args.get_u64("devices", 8)? as usize;
            moe::harness::distributed::run_distributed_demo(
                &artifacts, &cfg, devices, steps as usize,
            )?;
        }
        "table1" => tables::table1(&artifacts, steps)?,
        "table6" => tables::table6(&artifacts, steps)?,
        "table7" => tables::table7(&artifacts, steps)?,
        "table8" => tables::table8(&artifacts, steps)?,
        "table9" => tables::table9(&artifacts, steps)?,
        "fig2" => tables::fig2(&artifacts, steps, &args.get("side", "left"))?,
        "fig4" => tables::fig4(&artifacts, steps)?,
        "mt" => tables::mt_single(&artifacts, steps)?,
        "mt5" => tables::mt_multi(&artifacts, steps)?,
        "efficiency" => {
            let devices = args.get_u64("devices", 16)? as usize;
            let tokens = args.get_u64("tokens", 8192)? as usize;
            moe::harness::distributed::efficiency_report(
                &artifacts, devices, tokens,
            )?;
        }
        "cluster" => {
            // artifact-free: hierarchical routing + capacity dispatch on
            // the real engine at every rung of the expert ladder, priced
            // on the simulated multi-host cluster with the corrected
            // network-bytes accounting (local routes are free)
            let rows = args.get_u64("rows", 8)? as usize;
            let seed = args.get_u64("seed", 7)?;
            moe::harness::cluster_sim::run_scaling_study(
                rows,
                &[None, Some(1.0), Some(2.0)],
                seed,
            )?;
        }
        "chaos" => {
            // artifact-free: fault-rate x recovery-policy sweep on the
            // real engine + serve loop under a seeded FaultPlan; every
            // point asserts liveness (finite step latency, finite
            // outputs) and conservation (offered == ok + shed + failed)
            let rows = args.get_u64("rows", 8)? as usize;
            let seed = args.get_u64("seed", 7)?;
            moe::harness::chaos::run_chaos_study(
                rows,
                &[0.0, 0.05, 0.2, 0.5],
                seed,
            )?;
        }
        "serve" => {
            // artifact-free: the continuous micro-batching inference
            // runtime on the persistent engine, at 3 offered loads
            let devices = args.get_u64("devices", 4)? as usize;
            let requests = args.get_u64("requests", 400)? as usize;
            let seed = args.get_u64("seed", 17)?;
            moe::harness::workload::serve_load_curve(
                seed,
                devices,
                &[0.3, 1.0, 3.0],
                requests,
            )?;
        }
        "tenants" => {
            // artifact-free: per-tenant weighted-fair admission vs the
            // global-FIFO baseline under an adversarial heavy hitter,
            // with the victim-solo run as the isolation yardstick
            let devices = args.get_u64("devices", 2)? as usize;
            let victims = args.get_u64("victims", 16)? as usize;
            let seed = args.get_u64("seed", 17)?;
            moe::harness::workload::tenant_report(seed, devices, victims)?;
        }
        "trace" => {
            // artifact-free: span recording on for one streamed engine
            // step and one serve burst; outputs stay bit-identical to
            // untraced runs (tracing only reads clocks) while the
            // workers' route/compute/combine timelines land in a
            // Perfetto-loadable trace file
            let devices = args.get_u64("devices", 4)? as usize;
            let tokens = args.get_u64("tokens", 2048)? as usize;
            let requests = args.get_u64("requests", 64)? as usize;
            let seed = args.get_u64("seed", 17)?;
            let out = args.get("out", "trace.json");
            moe::harness::workload::trace_report(
                devices, tokens, requests, seed, &out,
            )?;
        }
        "info" => {
            let engine = Engine::new()?;
            let manifest = Manifest::load(&artifacts)?;
            println!("platform: {}", engine.platform());
            println!("configs in manifest:");
            for (name, e) in &manifest.configs {
                println!(
                    "  {:<22} middle={:<5} experts={:<6} params={:<9} \
                     ops/ts={:<9} artifacts={:?}",
                    name,
                    e.config.middle,
                    e.config.n_experts,
                    e.param_size,
                    e.config.ops_per_timestep,
                    e.artifacts.keys().collect::<Vec<_>>()
                );
            }
        }
        _ => usage(),
    }
    Ok(())
}
