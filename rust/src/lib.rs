//! # moe — Sparsely-Gated Mixture-of-Experts
//!
//! A three-layer reproduction of *Outrageously Large Neural Networks: The
//! Sparsely-Gated Mixture-of-Experts Layer* (Shazeer et al., ICLR 2017):
//!
//! - **L1** Pallas kernels + **L2** JAX model live in `python/compile/`
//!   and are AOT-lowered to HLO text once (`make artifacts`);
//! - **L3** (this crate) is the coordinator: it loads the artifacts via
//!   PJRT ([`runtime`]), owns training ([`train`]), the distributed MoE
//!   simulation ([`coordinator`], [`cluster`]) and every substrate the
//!   paper's evaluation needs ([`data`], [`ngram`], [`translate`],
//!   [`metrics`]).
//!
//! Python never runs on the training/serving path.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod gating;
pub mod harness;
pub mod metrics;
pub mod ngram;
pub mod runtime;
pub mod train;
pub mod translate;
pub mod util;
