//! # moe — Sparsely-Gated Mixture-of-Experts
//!
//! A three-layer reproduction of *Outrageously Large Neural Networks: The
//! Sparsely-Gated Mixture-of-Experts Layer* (Shazeer et al., ICLR 2017):
//!
//! - **L1** Pallas kernels + **L2** JAX model live in `python/compile/`
//!   and are AOT-lowered to HLO text once (`make artifacts`);
//! - **L3** (this crate) is the coordinator: it loads the artifacts via
//!   PJRT ([`runtime`]), owns training ([`train`]), the distributed MoE
//!   simulation ([`coordinator`], [`cluster`]) and every substrate the
//!   paper's evaluation needs ([`data`], [`ngram`], [`translate`],
//!   [`metrics`]).
//!
//! Python never runs on the training/serving path.
//!
//! ## Step execution architecture
//!
//! The distributed MoE step runs on a **persistent parallel execution
//! engine** ([`coordinator::engine::ExecutionEngine`]): one long-lived
//! worker thread per simulated device shard, fed over channels, with
//! pooled gather/compute/combine arenas so the hot path neither spawns
//! threads nor allocates per step.  Over-capacity expert batches are
//! processed in synchronous waves, and wave *w+1* is gathered while wave
//! *w* computes.  [`coordinator::Scheduler::execute_serial`] retains the
//! single-threaded reference path; `rust/tests/engine_parity.rs` proves
//! the two agree on randomized workloads, and
//! [`coordinator::StepStats`] reports the per-phase (gather / compute /
//! combine) and per-shard busy/idle breakdown that makes the §3.1
//! busiest-shard wait directly observable.
//!
//! The `xla` dependency is a vendored API-compatible stub by default
//! (see `vendor/xla`); artifact-backed paths report "PJRT unavailable"
//! until the real bindings are swapped in, while every Native path —
//! including the engine, benches, and the differential test suites —
//! is fully functional.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod gating;
pub mod harness;
pub mod metrics;
pub mod ngram;
pub mod runtime;
pub mod train;
pub mod translate;
pub mod util;
