//! # moe — Sparsely-Gated Mixture-of-Experts
//!
//! A three-layer reproduction of *Outrageously Large Neural Networks: The
//! Sparsely-Gated Mixture-of-Experts Layer* (Shazeer et al., ICLR 2017):
//!
//! - **L1** Pallas kernels + **L2** JAX model live in `python/compile/`
//!   and are AOT-lowered to HLO text once (`make artifacts`);
//! - **L3** (this crate) is the coordinator: it loads the artifacts via
//!   PJRT ([`runtime`]), owns training ([`train`]), the distributed MoE
//!   simulation ([`coordinator`], [`cluster`]) and every substrate the
//!   paper's evaluation needs ([`data`], [`ngram`], [`translate`],
//!   [`metrics`]).
//!
//! Python never runs on the training/serving path.
//!
//! ## Step execution architecture
//!
//! The distributed MoE step runs on a **persistent parallel execution
//! engine** ([`coordinator::engine::ExecutionEngine`]): one long-lived
//! worker thread per simulated device shard, fed over channels, with
//! pooled gather/compute/combine arenas so the hot path neither spawns
//! threads nor allocates per step.  Over-capacity expert batches are
//! processed in synchronous waves, and wave *w+1* is gathered while wave
//! *w* computes.
//!
//! The full step — gating included — runs as a **streaming
//! routing→dispatch pipeline** on the same pool
//! ([`coordinator::Scheduler::execute_streamed`]): row blocks are gated
//! in parallel with pre-drawn eq-4 noise, routed blocks feed an
//! incremental [`coordinator::PlanBuilder`], and each expert wave is
//! dispatched the moment its rows are final, so replica r+1 routes
//! while replica r's experts compute.  The Native wave size comes from
//! a [`coordinator::WavePolicy`] — fixed, or adapted each step from the
//! previous step's measured busiest-shard idle
//! ([`coordinator::AdaptiveWave`]).
//!
//! Step synchronization is **dependency-driven** rather than barriered:
//! per-replica completion records emit each replica's gate-weighted
//! combine as a worker-pool job the moment its last expert wave drains
//! (an async all-to-all of per-replica combine messages), so combine
//! runs hidden under later replicas' compute —
//! [`coordinator::PhaseNanos::overlap_ns`] /
//! [`coordinator::StepStats::combine_overlap_ratio`] measure how much.
//! [`train::Trainer::step_streamed`] trains the MoE sublayer on this
//! path with a native backward pass, no artifacts required.
//!
//! [`coordinator::Scheduler::execute_serial`] retains the
//! single-threaded reference path; `rust/tests/engine_parity.rs` proves
//! the engine and the streamed pipeline agree with it on randomized
//! workloads, and [`coordinator::StepStats`] reports the per-phase
//! (route / gather / compute / combine / overlap) and per-shard
//! busy/idle breakdown that makes the §3.1 busiest-shard wait directly
//! observable.
//!
//! ## Serving runtime
//!
//! [`serve`] turns the same engine into a **continuous micro-batching
//! inference runtime**: a bounded [`serve::RequestQueue`] with
//! admission control (reject / shed-oldest backpressure), a
//! [`serve::MicroBatcher`] that coalesces ragged requests into
//! engine-sized batches under a latency budget, and a
//! [`serve::ServeLoop`] driving forward-only steps on
//! [`coordinator::Scheduler::execute_forward`] with gating frozen from
//! a checkpoint or fresh init.  [`serve::ServeStats`] reports
//! per-request queue/compute/total latency percentiles, achieved
//! tokens/sec, batch occupancy and shed counts; the seeded open-loop
//! Poisson traffic generator in [`harness::workload`] drives
//! latency-vs-offered-load curves (`examples/serve_demo.rs`,
//! `benches/serve.rs` → `BENCH_serve.json`).  `rust/tests/serve.rs`
//! proves the serve path bit-identical to the serial oracle per
//! request.
//!
//! ## Kernel layer and quantized serving
//!
//! Every hot-path GEMM — gating logits, expert FFN forward, training
//! backward — dispatches through [`kernels`]: a [`kernels::MatmulKernel`]
//! trait with the original scalar implementation retained as the
//! bit-exact oracle plus explicit-SIMD kernels (AVX2+FMA on x86_64,
//! NEON on aarch64) selected at runtime by [`kernels::Kernel::select`]
//! (`MOE_KERNEL=scalar|avx2|neon` overrides for A/B runs;
//! [`coordinator::StepStats::kernel`] records which path ran).  Engine
//! and serial oracle share the selected kernel, so the differential
//! proofs stay bit-identical; kernel-vs-oracle and int8-vs-f32
//! comparisons are error-budgeted (`rust/tests/kernels.rs`,
//! `benches/kernels.rs` → `BENCH_kernels.json`).  For serving,
//! [`kernels::quant::QuantizedExpertWeights`] adds int8 weight-only
//! expert FFNs (per-output-channel symmetric scales, quantized at load
//! from f32 checkpoints) behind
//! [`serve::ServeConfig`]`::precision` —
//! [`kernels::quant::Precision::Int8`].
//!
//! ## Observability
//!
//! [`obs`] is the cross-cutting telemetry layer: per-worker lock-free
//! span rings record route/gather/compute/combine/retry intervals with
//! (step, shard, expert, chunk, replica) identity, drained by the
//! coordinator at step-end quiescence and exported as Chrome
//! trace-event JSON (`repro trace` → `trace.json`, loadable in
//! Perfetto); a unified [`obs::Registry`] of typed
//! counters/gauges/histograms receives every stats producer
//! ([`coordinator::StepStats`], [`serve::ServeStats`],
//! fault/capacity/cluster counters) and renders one snapshot as JSON or
//! Prometheus-style text.  Tracing is off by default (`MOE_TRACE=1` or
//! [`obs::ObsConfig`] enables it), costs one branch per job when off,
//! and is bit-neutral when on — `rust/tests/obs.rs` proves traced runs
//! bit-identical to untraced; `benches/obs.rs` → `BENCH_obs.json`
//! budgets the enabled overhead below 5%.
//!
//! The `xla` dependency is a vendored API-compatible stub by default
//! (see `vendor/xla`); artifact-backed paths report "PJRT unavailable"
//! until the real bindings are swapped in, while every Native path —
//! including the engine, benches, and the differential test suites —
//! is fully functional.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod gating;
pub mod harness;
pub mod kernels;
pub mod metrics;
pub mod ngram;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod translate;
pub mod util;
