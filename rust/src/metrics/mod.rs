//! Evaluation metrics and ops accounting.
//!
//! Implements the measures the paper's tables report: perplexity,
//! coefficient-of-variation balance stats (Table 6), BLEU is in
//! [`crate::translate::bleu`], and the FLOP accounting used for the
//! ops/timestep and TFLOPS/GPU columns (Tables 1, 7, 8).
//!
//! These are *evaluation* metrics, computed at reporting time from
//! model outputs.  Runtime telemetry — step phases, serve SLOs, fault
//! and traffic counters — lives in the unified registry instead
//! ([`crate::obs::Registry`]): producers publish typed
//! counters/gauges/histograms and every export (console line, JSON
//! snapshot, Prometheus text) renders from one snapshot.  Accumulators
//! here (e.g. [`Running`]) feed evaluation summaries; registry gauges
//! hold whatever scalar a run wants exported.

use crate::runtime::ModelConfig;

/// Perplexity from summed negative log likelihood.
pub fn perplexity(nll_sum: f64, tokens: f64) -> f64 {
    (nll_sum / tokens.max(1.0)).exp()
}

/// Max-over-mean load (Table 6 rightmost column).
pub fn max_over_mean(v: &[f32]) -> f32 {
    if v.is_empty() {
        return 0.0;
    }
    let mean = v.iter().sum::<f32>() / v.len() as f32;
    let max = v.iter().cloned().fold(f32::MIN, f32::max);
    max / (mean + 1e-10)
}

/// Simple online mean/min/max accumulator for step metrics.
#[derive(Clone, Debug)]
pub struct Running {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

/// Must match [`new`](Running::new): the derived impl used to start
/// `min`/`max` at 0.0, so a `Running::default()` that only ever saw
/// positive samples reported `min == 0.0` (and negative-only samples
/// reported `max == 0.0`) — the ±infinity identities are what make the
/// first `push` win unconditionally.
impl Default for Running {
    fn default() -> Self {
        Self::new()
    }
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// FLOP accounting in the paper's convention (§5.1): ops/timestep counts
/// forward multiply-adds excluding embedding and softmax; the training
/// figure (for TFLOPS/GPU) counts a multiply-add as TWO ops, includes the
/// backward pass (2x forward) and the softmax layer.
#[derive(Clone, Copy, Debug)]
pub struct OpsModel {
    /// forward MACs per token, excl. embedding & softmax (manifest value)
    pub fwd_macs_per_token: u64,
    pub d_model: u64,
    pub vocab: u64,
}

impl OpsModel {
    pub fn from_config(c: &ModelConfig) -> Self {
        OpsModel {
            fwd_macs_per_token: c.ops_per_timestep,
            d_model: c.d_model as u64,
            vocab: c.vocab as u64,
        }
    }

    /// ops/timestep as the paper reports it.
    pub fn ops_per_timestep(&self) -> u64 {
        self.fwd_macs_per_token
    }

    /// Total training FLOPs for `tokens` tokens: fwd + bwd (2x), softmax
    /// included, MAC = 2 ops.
    pub fn train_flops(&self, tokens: u64) -> u64 {
        let softmax_macs = self.d_model * self.vocab;
        let fwd = self.fwd_macs_per_token + softmax_macs;
        // fwd + 2x for backward, times 2 ops per MAC
        fwd * 3 * 2 * tokens
    }

    /// TFLOPS/device given a measured step time.
    pub fn tflops_per_device(
        &self,
        tokens_per_step: u64,
        step_secs: f64,
        devices: usize,
    ) -> f64 {
        self.train_flops(tokens_per_step) as f64
            / step_secs.max(1e-12)
            / devices.max(1) as f64
            / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        // uniform over V: nll = ln V per token
        let v: f64 = 64.0;
        let ppl = perplexity(v.ln() * 100.0, 100.0);
        assert!((ppl - 64.0).abs() < 1e-9);
    }

    #[test]
    fn max_over_mean_balanced_is_one() {
        assert!((max_over_mean(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-6);
        assert!(max_over_mean(&[0.0, 4.0]) > 1.9);
    }

    #[test]
    fn running_stats() {
        let mut r = Running::new();
        for v in [1.0, 2.0, 6.0] {
            r.push(v);
        }
        assert_eq!(r.mean(), 3.0);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 6.0);
    }

    #[test]
    fn default_matches_new_so_first_push_wins_min_and_max() {
        // regression: the derived Default started min/max at 0.0, so a
        // defaulted accumulator fed only positive samples reported
        // min == 0.0 (a value it never saw)
        let mut d = Running::default();
        for v in [3.0, 5.0] {
            d.push(v);
        }
        assert_eq!(d.min, 3.0);
        assert_eq!(d.max, 5.0);
        let mut neg = Running::default();
        neg.push(-2.0);
        assert_eq!(neg.max, -2.0);
        assert_eq!(neg.min, -2.0);
        // and the empty default is identical to the empty new()
        let (a, b) = (Running::default(), Running::new());
        assert_eq!(a.n, b.n);
        assert_eq!(a.sum, b.sum);
        assert_eq!(a.min, b.min);
        assert_eq!(a.max, b.max);
    }

    #[test]
    fn flop_accounting_scales() {
        let m = OpsModel { fwd_macs_per_token: 8_000_000, d_model: 512, vocab: 10_000 };
        assert_eq!(m.ops_per_timestep(), 8_000_000);
        let f1 = m.train_flops(1);
        assert_eq!(f1, (8_000_000 + 512 * 10_000) * 6);
        // tflops: 1M tokens/step in 1s on 4 devices
        let t = m.tflops_per_device(1_000_000, 1.0, 4);
        assert!((t - f1 as f64 * 1_000_000.0 / 4.0 / 1e12).abs() < 1e-9);
    }
}
