//! Translation substrate: beam-search decoding over the AOT decode
//! artifact and BLEU scoring (multi-bleu.pl semantics), backing the
//! Table 2–5 analogues.

pub mod beam;
pub mod bleu;

pub use beam::{BeamDecoder, Hypothesis};
pub use bleu::bleu;
