//! BLEU (Papineni et al. 2002) with the same conventions as the
//! `multi-bleu.pl` script the paper reports (§E Metrics): corpus-level,
//! n-grams up to 4, clipped counts, geometric mean with floor smoothing
//! off, and the brevity penalty.

use std::collections::HashMap;

fn ngram_counts(tokens: &[i32], n: usize) -> HashMap<&[i32], u64> {
    let mut m: HashMap<&[i32], u64> = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus BLEU over (hypothesis, reference) pairs, in [0, 100].
pub fn bleu(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    const N: usize = 4;
    let mut matched = [0u64; N];
    let mut total = [0u64; N];
    let mut hyp_len = 0u64;
    let mut ref_len = 0u64;
    for (hyp, re) in pairs {
        hyp_len += hyp.len() as u64;
        ref_len += re.len() as u64;
        for n in 1..=N {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(re, n);
            for (g, c) in &h {
                let rc = r.get(g).copied().unwrap_or(0);
                matched[n - 1] += (*c).min(rc);
            }
            total[n - 1] += hyp.len().saturating_sub(n - 1) as u64;
        }
    }
    let mut log_p = 0f64;
    for n in 0..N {
        if matched[n] == 0 || total[n] == 0 {
            return 0.0;
        }
        log_p += (matched[n] as f64 / total[n] as f64).ln();
    }
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len.max(1) as f64).exp()
    };
    100.0 * bp * (log_p / N as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let pairs = vec![(vec![1, 2, 3, 4, 5], vec![1, 2, 3, 4, 5])];
        assert!((bleu(&pairs) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_0() {
        let pairs = vec![(vec![1, 2, 3, 4, 5], vec![6, 7, 8, 9, 10])];
        assert_eq!(bleu(&pairs), 0.0);
    }

    #[test]
    fn partial_overlap_in_between() {
        let pairs = vec![(vec![1, 2, 3, 4, 9], vec![1, 2, 3, 4, 5])];
        let b = bleu(&pairs);
        assert!(b > 0.0 && b < 100.0, "bleu {b}");
    }

    #[test]
    fn brevity_penalty_punishes_short_hypotheses() {
        // same matched prefix, shorter hypothesis -> lower BLEU
        let long = vec![(vec![1, 2, 3, 4, 5, 6], vec![1, 2, 3, 4, 5, 6])];
        let short = vec![(vec![1, 2, 3, 4], vec![1, 2, 3, 4, 5, 6])];
        assert!(bleu(&short) < bleu(&long));
    }

    #[test]
    fn clipping_limits_repeats() {
        // "the the the ..." style inflation must not score
        let pairs = vec![(vec![7, 7, 7, 7, 7, 7], vec![7, 1, 2, 3, 4, 5])];
        assert_eq!(bleu(&pairs), 0.0); // no 2-gram match -> 0 by convention
    }

    #[test]
    fn corpus_level_pools_counts() {
        let a = vec![
            (vec![1, 2, 3, 4], vec![1, 2, 3, 4]),
            (vec![9, 9, 9, 9], vec![5, 6, 7, 8]),
        ];
        let b = bleu(&a);
        assert!(b > 0.0 && b < 100.0);
    }
}
