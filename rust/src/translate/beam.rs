//! Beam search over the incremental decode artifact.
//!
//! The decode artifact has a fixed batch dimension (`decode_batch` in the
//! manifest); beam slots ride in that dimension, so a beam of width
//! w <= decode_batch costs one artifact call per output token, same as
//! the paper's GNMT-style batched beam.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::{ConfigEntry, Executable, Host, TensorF, TensorI};

#[derive(Clone, Debug)]
pub struct Hypothesis {
    pub tokens: Vec<i32>,
    pub log_prob: f64,
    pub finished: bool,
}

impl Hypothesis {
    /// Length-normalised score (GNMT alpha = 0.6 simplified to 1.0/len).
    pub fn score(&self) -> f64 {
        self.log_prob / self.tokens.len().max(1) as f64
    }
}

pub struct BeamDecoder {
    exe: Arc<Executable>,
    pub batch: usize,
    n_lstm: usize,
    d_h: usize,
    d_out: usize,
    vocab: usize,
}

struct State {
    cs: TensorF,
    hs: TensorF,
}

impl BeamDecoder {
    pub fn new(exe: Arc<Executable>, entry: &ConfigEntry) -> Self {
        let c = &entry.config;
        BeamDecoder {
            exe,
            batch: entry.decode_batch,
            n_lstm: entry.n_lstm,
            d_h: c.lstm_hidden,
            d_out: if c.lstm_proj > 0 { c.lstm_proj } else { c.lstm_hidden },
            vocab: c.vocab,
        }
    }

    fn zero_state(&self) -> State {
        State {
            cs: TensorF::zeros(vec![self.n_lstm, self.batch, self.d_h]),
            hs: TensorF::zeros(vec![self.n_lstm, self.batch, self.d_out]),
        }
    }

    /// One artifact call: tokens (batch,) -> (logits (batch, vocab)).
    fn step(&self, params: &Host, st: &mut State, tokens: &[i32])
        -> Result<TensorF> {
        let outs = self.exe.run(&[
            params.clone(),
            Host::F32(std::mem::replace(&mut st.cs, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut st.hs, TensorF::zeros(vec![0]))),
            Host::I32(TensorI::new(vec![self.batch], tokens.to_vec())),
        ])?;
        let mut it = outs.into_iter();
        let logits = it.next().unwrap().into_f32()?;
        st.cs = it.next().unwrap().into_f32()?;
        st.hs = it.next().unwrap().into_f32()?;
        Ok(logits)
    }

    /// Permute beam slots of the recurrent state: slot i <- old slot
    /// `perm[i]`.
    fn reorder(&self, st: &mut State, perm: &[usize]) {
        for t in [&mut st.cs, &mut st.hs] {
            let (l, b) = (t.shape[0], t.shape[1]);
            let d = t.shape[2];
            let old = t.data.clone();
            for layer in 0..l {
                for (slot, &src) in perm.iter().enumerate() {
                    let dst_off = (layer * b + slot) * d;
                    let src_off = (layer * b + src) * d;
                    t.data[dst_off..dst_off + d]
                        .copy_from_slice(&old[src_off..src_off + d]);
                }
            }
        }
    }

    /// Decode continuations of `prefix`, returning up to `beam` finished
    /// hypotheses (best first).  `eos` terminates a hypothesis.
    pub fn decode(&self, params: &TensorF, prefix: &[i32], beam: usize,
                  max_len: usize, eos: i32) -> Result<Vec<Hypothesis>> {
        if beam == 0 || beam > self.batch {
            bail!("beam width must be in 1..={}", self.batch);
        }
        if prefix.is_empty() {
            bail!("prefix must be non-empty");
        }
        let params = Host::F32(params.clone());
        let mut st = self.zero_state();
        // feed the prefix; all slots identical
        let mut logits = TensorF::zeros(vec![self.batch, self.vocab]);
        for &tok in prefix {
            logits = self.step(&params, &mut st, &vec![tok; self.batch])?;
        }
        let mut hyps: Vec<Hypothesis> = vec![
            Hypothesis { tokens: vec![], log_prob: 0.0, finished: false };
            beam
        ];
        let mut first = true;
        let mut done: Vec<Hypothesis> = Vec::new();
        for _ in 0..max_len {
            // expand: candidates (slot, token, score)
            let mut cands: Vec<(usize, i32, f64)> = Vec::new();
            let active: Vec<usize> =
                (0..hyps.len()).filter(|&i| !hyps[i].finished).collect();
            if active.is_empty() {
                break;
            }
            for &slot in &active {
                let row = logits.row(slot);
                let lse = log_sum_exp(row);
                // on the first expansion only slot 0 is meaningful (all
                // slots identical) — expanding all would duplicate
                if first && slot > 0 {
                    continue;
                }
                for (tok, &lg) in row.iter().enumerate() {
                    cands.push((
                        slot,
                        tok as i32,
                        hyps[slot].log_prob + (lg as f64 - lse),
                    ));
                }
            }
            first = false;
            cands.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
            cands.truncate(beam);
            // rebuild beam + state permutation
            let mut perm = Vec::with_capacity(self.batch);
            let mut new_hyps = Vec::with_capacity(beam);
            let mut next_tokens = Vec::with_capacity(self.batch);
            for &(slot, tok, lp) in &cands {
                let mut h = hyps[slot].clone();
                h.tokens.push(tok);
                h.log_prob = lp;
                if tok == eos || h.tokens.len() >= max_len {
                    h.finished = true;
                    done.push(h.clone());
                }
                perm.push(slot);
                next_tokens.push(tok);
                new_hyps.push(h);
            }
            while perm.len() < self.batch {
                perm.push(0);
                next_tokens.push(eos);
            }
            self.reorder(&mut st, &perm);
            hyps = new_hyps;
            if hyps.iter().all(|h| h.finished) {
                break;
            }
            logits = self.step(&params, &mut st, &next_tokens)?;
        }
        for h in hyps {
            if !h.finished {
                done.push(h);
            }
        }
        done.sort_by(|a, b| b.score().partial_cmp(&a.score()).unwrap());
        done.truncate(beam);
        Ok(done)
    }
}

fn log_sum_exp(v: &[f32]) -> f64 {
    let m = v.iter().cloned().fold(f32::MIN, f32::max) as f64;
    m + v.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_stable() {
        let v = vec![1000.0f32, 1000.0];
        let l = log_sum_exp(&v);
        assert!((l - (1000.0 + 2f64.ln())).abs() < 1e-6);
    }

    #[test]
    fn hypothesis_score_normalises() {
        let h = Hypothesis { tokens: vec![1, 2], log_prob: -2.0, finished: true };
        assert!((h.score() + 1.0).abs() < 1e-9);
    }
}
