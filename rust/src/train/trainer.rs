//! Trainer: drives `step_<cfg>.hlo.txt` (params, m, v, tokens, step) ->
//! (params', m', v', metrics) and `eval_<cfg>.hlo.txt`.
//!
//! The LR schedule, optimizer, dropout and gating noise all live INSIDE
//! the artifact (keyed by the step counter input), so the artifact loop
//! is pure data movement: batch in, metrics out.
//!
//! # Artifact-free streamed training
//!
//! Training no longer *requires* the artifact path:
//! [`Trainer::native`] builds a trainer from a bare [`ModelConfig`]
//! (no manifest, no PJRT), and [`Trainer::step_streamed`] runs the MoE
//! sublayer forward on [`Scheduler::execute_streamed`] — the
//! dependency-driven pipelined engine — then backpropagates **exactly**
//! through the gate-weighted combine (eq 1), the expert FFNs, *and the
//! gating network itself*: task gradients through the noisy top-k
//! softmax into `w_g`/`w_noise` (via the pre-drawn eq-4 noise retained
//! from the forward), plus the eq-6/7 importance and eq-8 smooth-load
//! balance-loss gradients ([`crate::gating::backward`], proven against
//! central finite differences in `rust/tests/grad_check.rs`).  Updates
//! use the artifact path's Adam ([`crate::train::optimizer`]) with
//! per-tensor moments that checkpoint through
//! `checkpoint::save_streamed` / `load_streamed`.  The loss is mean
//! squared error against caller-provided targets, the regression
//! framing the sublayer admits without the LSTM stack; per-step balance
//! CVs and the balance loss are reported alongside it.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::scheduler::ExpertWeights;
use crate::coordinator::{
    Dispatcher, Router, Scheduler, StepStats, StreamedStep,
};
use crate::data::Batcher;
use crate::gating::backward::{
    cv_squared_grad, flat_gate_backward, hierarchical_gate_backward, GateGrads,
};
use crate::gating::noisy_topk::{cv_squared, matmul, matmul_nt, matmul_tn};
use crate::metrics::perplexity;
use crate::runtime::{
    ConfigEntry, Engine, ExecPhases, Executable, Host, Manifest, ModelConfig,
    TensorF, TensorI,
};
use crate::train::optimizer::{AdamParams, StreamedOptState};
use crate::util::rng::Rng;

/// Decoded metrics vector of one step (names from the manifest).
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub nll: f64,
    pub balance_loss: f64,
    pub cv_importance: f64,
    pub cv_load: f64,
    pub max_over_mean_load: f64,
    pub dropped_frac: f64,
    pub grad_norm: f64,
    pub lr: f64,
    pub step_time: f64,
    /// stage-in / execute / stage-out breakdown of the step artifact
    /// call, mirroring the coordinator's gather/compute/combine split
    pub phases: ExecPhases,
}

impl StepMetrics {
    fn from_vec(step: u64, names: &[String], v: &[f32], dt: f64,
                phases: ExecPhases) -> Self {
        let get = |n: &str| {
            names
                .iter()
                .position(|x| x == n)
                .map(|i| v[i] as f64)
                .unwrap_or(f64::NAN)
        };
        StepMetrics {
            step,
            loss: get("loss"),
            nll: get("nll"),
            balance_loss: get("balance_loss"),
            cv_importance: get("cv_importance"),
            cv_load: get("cv_load"),
            max_over_mean_load: get("max_over_mean_load"),
            dropped_frac: get("dropped_frac"),
            grad_norm: get("grad_norm"),
            lr: get("lr"),
            step_time: dt,
            phases,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub nll_sum: f64,
    pub tokens: f64,
}

impl EvalResult {
    pub fn perplexity(&self) -> f64 {
        perplexity(self.nll_sum, self.tokens)
    }
}

/// Model + optimizer state living on the rust side between steps.
pub struct TrainState {
    pub params: TensorF,
    pub m: TensorF,
    pub v: TensorF,
    pub step: u64,
}

/// Model + optimizer state of the artifact-free streamed path: the MoE
/// sublayer's router and expert weights — *all* trained natively — plus
/// the per-tensor Adam moments.
pub struct StreamedTrainState {
    pub router: Router,
    pub weights: Vec<ExpertWeights>,
    pub opt: StreamedOptState,
    pub step: u64,
}

/// Metrics of one artifact-free streamed training step.
#[derive(Clone, Debug)]
pub struct StreamedStepMetrics {
    pub step: u64,
    /// task term: mean squared error over every output element
    pub loss: f64,
    /// auxiliary term: w_importance·CV²(Importance) + w_load·CV²(Load)
    /// (eq 6–8) as evaluated this step — the quantity whose gradients
    /// train the gating network
    pub balance_loss: f64,
    /// l2 norm of every gradient this step (experts + gating nets)
    pub grad_norm: f64,
    /// CV(Importance) over the step's merged routing decisions (eq 6)
    pub cv_importance: f64,
    /// CV(Load) over the step's merged routing decisions (eq 8–10)
    pub cv_load: f64,
    pub step_time: f64,
    /// full engine telemetry of the forward step (overlap ratio et al.
    /// via [`StepStats::combine_overlap_ratio`])
    pub stats: StepStats,
}

/// Knobs of one streamed training step.
/// [`Trainer::streamed_options`] fills the balance-loss weights from
/// the config; `train_gating: false` reproduces the frozen-gating
/// baseline (experts-only backward) for ablations.
#[derive(Clone, Copy, Debug)]
pub struct StreamedStepOptions {
    pub lr: f32,
    pub train_gating: bool,
    pub w_importance: f32,
    pub w_load: f32,
}

/// Loss breakdown of one streamed step's backward pass.
#[derive(Clone, Debug)]
pub struct StreamedLoss {
    pub task: f64,
    pub balance: f64,
    pub cv_importance: f64,
    pub cv_load: f64,
    pub grad_norm: f64,
}

/// Every gradient of one streamed step, shaped like the model tensors.
pub struct StreamedGrads {
    /// per expert: (∂L/∂w_in, ∂L/∂w_out)
    pub experts: Vec<(Vec<f32>, Vec<f32>)>,
    /// gating-net gradients; `None` when the step froze gating
    pub gate: Option<GateGrads>,
}

/// The exact native backward of one streamed MoE step — public so the
/// finite-difference harness (`rust/tests/grad_check.rs`) can check
/// every analytic gradient without going through an optimizer update.
///
/// Takes the finished forward ([`StreamedStep`]: outputs, retained
/// decisions + eq-4 noise, dispatch plan) and produces the task (MSE
/// against `targets`) + balance loss breakdown and the gradients of
/// every trainable tensor: expert FFNs, `w_g`, `w_noise` (and the
/// hierarchical secondaries).  The load loss differentiates through
/// the smooth eq-10 estimator only where it is defined (flat router,
/// noise retained, k < n); elsewhere Load is piecewise constant and
/// contributes no gradient.
#[allow(clippy::too_many_arguments)]
pub fn streamed_backward(
    router: &Router,
    weights: &[ExpertWeights],
    xs: &[&TensorF],
    targets: &[TensorF],
    s: &StreamedStep,
    w_importance: f32,
    w_load: f32,
    train_gating: bool,
) -> Result<(StreamedLoss, StreamedGrads)> {
    let d = xs
        .first()
        .map(|t| t.shape[1])
        .ok_or_else(|| anyhow!("no replica inputs"))?;
    let n = router.n_experts;
    if s.decisions.len() != xs.len() {
        bail!(
            "step retained {} decisions for {} replicas (forward-only \
             steps cannot be backpropagated)",
            s.decisions.len(),
            xs.len()
        );
    }
    if targets.len() != xs.len() {
        bail!("{} replica inputs but {} targets", xs.len(), targets.len());
    }
    for (i, (x, t)) in xs.iter().zip(targets.iter()).enumerate() {
        if x.shape != t.shape {
            bail!(
                "replica {i}: input shape {:?} vs target shape {:?}",
                x.shape,
                t.shape
            );
        }
    }

    // task loss and ∂L/∂y per replica
    let n_el: usize = s.outs.iter().map(|t| t.data.len()).sum();
    let scale = 2.0 / n_el.max(1) as f32;
    let mut task = 0.0f64;
    let mut grads_y: Vec<Vec<f32>> = Vec::with_capacity(s.outs.len());
    for (y, t) in s.outs.iter().zip(targets.iter()) {
        let g = y
            .data
            .iter()
            .zip(t.data.iter())
            .map(|(a, b)| {
                let e = a - b;
                task += (e * e) as f64;
                scale * e
            })
            .collect();
        grads_y.push(g);
    }
    task /= n_el.max(1) as f64;

    // balance statistics over the merged decisions, and the CV²
    // gradient coefficients the gating backward chains through
    let mut imp = vec![0f32; n];
    let mut load = vec![0f32; n];
    for dec in &s.decisions {
        for (a, v) in imp.iter_mut().zip(dec.importance.iter()) {
            *a += v;
        }
        for (a, v) in load.iter_mut().zip(dec.load.iter()) {
            *a += v;
        }
    }
    let cv2_imp = cv_squared(&imp);
    let cv2_load = cv_squared(&load);
    let balance = (w_importance * cv2_imp + w_load * cv2_load) as f64;
    let d_imp: Vec<f32> = cv_squared_grad(&imp)
        .iter()
        .map(|g| g * w_importance)
        .collect();
    let smooth_load = train_gating
        && w_load != 0.0
        && router.groups == 0
        && router.k < n
        && s.decisions.iter().all(|dec| dec.noise.is_some());
    let d_load: Vec<f32> = if smooth_load {
        cv_squared_grad(&load).iter().map(|g| g * w_load).collect()
    } else {
        vec![0.0; n]
    };

    // per-token ∂L_task/∂gate accumulators, aligned with the decisions
    let mut d_gates: Vec<Vec<Vec<f32>>> = s
        .decisions
        .iter()
        .map(|dec| {
            dec.per_token
                .iter()
                .map(|tok| vec![0f32; tok.experts.len()])
                .collect()
        })
        .collect();

    // backprop per expert: dL/d(expert row) = gate · dL/dy[token]
    // (eq 1 is bilinear), then the standard two-layer relu-FFN
    // backward; gather reuses the step's plan.  The recomputed expert
    // outputs also yield the task's gate gradients: ∂L/∂gate = gy · y.
    let mut grad_sq = 0.0f64;
    let mut expert_grads: Vec<(Vec<f32>, Vec<f32>)> =
        Vec::with_capacity(weights.len());
    for (e, w) in weights.iter().enumerate() {
        let batch = &s.plan.per_expert[e];
        let rows = batch.tokens.len();
        let h = w.hidden;
        if rows == 0 {
            expert_grads.push((vec![0.0; d * h], vec![0.0; h * d]));
            continue;
        }
        let x = Dispatcher::gather(&s.plan, e, xs);
        // recompute hidden activations (cheaper than caching them
        // across the engine boundary)
        let mut hid = vec![0f32; rows * h];
        matmul(&x.data, &w.w_in, &mut hid, rows, d, h);
        for v in hid.iter_mut() {
            *v = v.max(0.0);
        }
        let mut y = vec![0f32; rows * d];
        if train_gating {
            matmul(&hid, &w.w_out, &mut y, rows, h, d);
        }
        let mut gout = vec![0f32; rows * d];
        for (slot, (addr, gate)) in
            batch.tokens.iter().zip(batch.gates.iter()).enumerate()
        {
            let gy = &grads_y[addr.replica][addr.row * d..(addr.row + 1) * d];
            for (o, g) in gout[slot * d..(slot + 1) * d].iter_mut().zip(gy) {
                *o = gate * g;
            }
            if train_gating {
                let yrow = &y[slot * d..(slot + 1) * d];
                let dg: f32 =
                    gy.iter().zip(yrow.iter()).map(|(a, b)| a * b).sum();
                let tok = &s.decisions[addr.replica].per_token[addr.row];
                // slot of this expert in the token's gate vector (first
                // match; gating-produced selections are distinct)
                if let Some(p) = tok.experts.iter().position(|&te| te == e) {
                    d_gates[addr.replica][addr.row][p] += dg;
                }
            }
        }
        // dW_out = hiddenᵀ · gout
        let mut d_wout = vec![0f32; h * d];
        matmul_tn(&hid, &gout, &mut d_wout, rows, h, d);
        // d_hidden = gout · W_outᵀ, masked by the relu
        let mut d_hid = vec![0f32; rows * h];
        matmul_nt(&gout, &w.w_out, &mut d_hid, rows, h, d);
        for (dh, hv) in d_hid.iter_mut().zip(hid.iter()) {
            if *hv <= 0.0 {
                *dh = 0.0;
            }
        }
        // dW_in = xᵀ · d_hidden
        let mut d_win = vec![0f32; d * h];
        matmul_tn(&x.data, &d_hid, &mut d_win, rows, d, h);
        for g in d_wout.iter().chain(d_win.iter()) {
            grad_sq += (*g as f64) * (*g as f64);
        }
        expert_grads.push((d_win, d_wout));
    }

    // gating backward per replica: task + importance terms through the
    // top-k softmax, load through the smooth estimator — all on the
    // noise retained from the forward
    let gate = if train_gating {
        let mut acc: Option<GateGrads> = None;
        for (r, dec) in s.decisions.iter().enumerate() {
            let x = xs[r];
            let b = x.shape[0];
            let dldg: Vec<Vec<f32>> = dec
                .per_token
                .iter()
                .zip(d_gates[r].iter())
                .map(|(tok, task_g)| {
                    tok.experts
                        .iter()
                        .zip(task_g.iter())
                        .map(|(&e, &tg)| tg + d_imp[e])
                        .collect()
                })
                .collect();
            let eps_pri = dec.noise.as_ref().and_then(|ns| {
                (!ns.primary.is_empty()).then_some(ns.primary.as_slice())
            });
            let g = if router.groups > 0 {
                let gs = n / router.groups;
                let wsec = router.w_g_sec.as_deref().ok_or_else(|| {
                    anyhow!("hierarchical router needs secondary gates")
                })?;
                let eps_sec = dec.noise.as_ref().and_then(|ns| {
                    (!ns.secondary.is_empty())
                        .then_some(ns.secondary.as_slice())
                });
                hierarchical_gate_backward(
                    &x.data,
                    b,
                    d,
                    &router.w_g,
                    router.w_noise.as_deref(),
                    wsec,
                    router.w_n_sec.as_deref(),
                    router.groups,
                    gs,
                    router.k,
                    eps_pri,
                    eps_sec,
                    &dec.per_token,
                    &dldg,
                )
            } else {
                flat_gate_backward(
                    &x.data,
                    b,
                    d,
                    &router.w_g,
                    router.w_noise.as_deref(),
                    n,
                    router.k,
                    eps_pri,
                    &dec.per_token,
                    &dldg,
                    &d_load,
                )
            };
            match &mut acc {
                Some(t) => t.add(&g),
                None => acc = Some(g),
            }
        }
        if let Some(g) = &acc {
            grad_sq += g.sq_norm();
        }
        acc
    } else {
        None
    };

    Ok((
        StreamedLoss {
            task,
            balance,
            cv_importance: (cv2_imp as f64).sqrt(),
            cv_load: (cv2_load as f64).sqrt(),
            grad_norm: grad_sq.sqrt(),
        },
        StreamedGrads { experts: expert_grads, gate },
    ))
}

pub struct Trainer {
    pub entry: ConfigEntry,
    /// `None` on [`native`](Self::native) trainers (bare checkout, no
    /// artifacts) — the artifact methods error cleanly, the streamed
    /// path works
    step_exe: Option<Arc<Executable>>,
    eval_exe: Option<Arc<Executable>>,
    init_exe: Option<Arc<Executable>>,
    pub tokens_per_step: u64,
}

impl Trainer {
    pub fn new(engine: &Engine, manifest: &Manifest, cfg: &str) -> Result<Self> {
        let entry = manifest.config(cfg)?.clone();
        Ok(Trainer {
            step_exe: Some(engine.load(manifest, cfg, "step")?),
            eval_exe: Some(engine.load(manifest, cfg, "eval")?),
            init_exe: Some(engine.load(manifest, cfg, "init")?),
            tokens_per_step: (entry.config.batch * entry.config.seq_len) as u64,
            entry,
        })
    }

    /// Artifact-free construction from a bare [`ModelConfig`] — no
    /// manifest, no PJRT, works on a fresh offline checkout.  Only the
    /// streamed path ([`init_streamed`](Self::init_streamed) /
    /// [`step_streamed`](Self::step_streamed)) is available.
    pub fn native(config: ModelConfig) -> Trainer {
        let tokens_per_step = (config.batch * config.seq_len) as u64;
        Trainer {
            entry: ConfigEntry {
                config,
                metric_names: Vec::new(),
                params: Vec::new(),
                param_size: 0,
                opt_sizes: (0, 0),
                decode_batch: 0,
                n_lstm: 0,
                artifacts: BTreeMap::new(),
            },
            step_exe: None,
            eval_exe: None,
            init_exe: None,
            tokens_per_step,
        }
    }

    fn artifact(exe: &Option<Arc<Executable>>, kind: &str)
        -> Result<Arc<Executable>> {
        exe.clone().ok_or_else(|| {
            anyhow!(
                "trainer was built without artifacts ({kind} unavailable); \
                 use the streamed path (init_streamed / step_streamed)"
            )
        })
    }

    /// Initialize parameters via the init artifact (gating nets start at
    /// zero per Appendix A).
    pub fn init(&self, seed: i32) -> Result<TrainState> {
        let outs = Self::artifact(&self.init_exe, "init")?
            .run(&[Host::I32(TensorI::scalar(seed))])
            .context("running init artifact")?;
        let mut it = outs.into_iter();
        Ok(TrainState {
            params: it.next().unwrap().into_f32()?,
            m: it.next().unwrap().into_f32()?,
            v: it.next().unwrap().into_f32()?,
            step: 0,
        })
    }

    /// One training step; consumes and replaces the state buffers.
    pub fn step(&self, state: &mut TrainState, tokens: &TensorI)
        -> Result<StepMetrics> {
        let t0 = Instant::now();
        let (outs, phases) = Self::artifact(&self.step_exe, "step")?.run_phased(&[
            Host::F32(std::mem::replace(&mut state.params, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.m, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.v, TensorF::zeros(vec![0]))),
            Host::I32(tokens.clone()),
            Host::I32(TensorI::scalar(state.step as i32)),
        ])?;
        let mut it = outs.into_iter();
        state.params = it.next().unwrap().into_f32()?;
        state.m = it.next().unwrap().into_f32()?;
        state.v = it.next().unwrap().into_f32()?;
        let metrics = it.next().unwrap().into_f32()?;
        let sm = StepMetrics::from_vec(
            state.step,
            &self.entry.metric_names,
            &metrics.data,
            t0.elapsed().as_secs_f64(),
            phases,
        );
        state.step += 1;
        Ok(sm)
    }

    /// Run `n_batches` of held-out data through the eval artifact.
    pub fn evaluate(&self, state: &TrainState, batcher: &mut Batcher,
                    n_batches: usize) -> Result<EvalResult> {
        let eval_exe = Self::artifact(&self.eval_exe, "eval")?;
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for _ in 0..n_batches {
            let tokens = batcher.next_batch();
            let outs = eval_exe.run(&[params.clone(), Host::I32(tokens)])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Evaluate over explicit token tensors (translation path).
    pub fn evaluate_tokens(&self, state: &TrainState, batches: &[TensorI])
        -> Result<EvalResult> {
        let eval_exe = Self::artifact(&self.eval_exe, "eval")?;
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for tokens in batches {
            let outs =
                eval_exe.run(&[params.clone(), Host::I32(tokens.clone())])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Initialize the artifact-free streamed state from the config
    /// dims: small random expert weights, gating weights perturbed
    /// slightly away from the Appendix-A zero init so routing is
    /// non-degenerate from step 0, and fresh (zero) Adam moments.
    pub fn init_streamed(&self, seed: u64) -> StreamedTrainState {
        let c = &self.entry.config;
        let (d, h, n, k) = (c.d_model, c.expert_hidden, c.n_experts, c.k);
        let mut rng = Rng::new(seed);
        let scale = (2.0 / d.max(1) as f32).sqrt() * 0.5;
        let weights: Vec<ExpertWeights> = (0..n)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * scale).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * scale).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d,
            n,
            k,
            (0..d * n).map(|_| rng.normal_f32() * 0.1).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.1).collect()),
        );
        let opt = StreamedOptState::zeros(&router, &weights);
        StreamedTrainState { router, weights, opt, step: 0 }
    }

    /// Default options for [`Self::step_streamed_with`]: gating
    /// learning on, balance-loss weights from the config.
    pub fn streamed_options(&self, lr: f32) -> StreamedStepOptions {
        StreamedStepOptions {
            lr,
            train_gating: true,
            w_importance: self.entry.config.w_importance as f32,
            w_load: self.entry.config.w_load as f32,
        }
    }

    /// One artifact-free training step of the MoE sublayer (module
    /// docs) with the default options: forward on
    /// [`Scheduler::execute_streamed`], MSE loss against `targets`,
    /// exact backprop through the combine, the expert FFNs *and* the
    /// gating network (balance losses included), Adam update.  `rng`
    /// draws the eq-4 routing noise (`None` = deterministic routing —
    /// gating still trains through the clean logits, but the smooth
    /// load loss needs noise).  Runs end to end on a bare offline
    /// checkout.
    pub fn step_streamed(
        &self,
        sched: &Scheduler,
        state: &mut StreamedTrainState,
        xs: &[TensorF],
        targets: &[TensorF],
        lr: f32,
        rng: Option<&mut Rng>,
    ) -> Result<StreamedStepMetrics> {
        let opts = self.streamed_options(lr);
        self.step_streamed_with(sched, state, xs, targets, rng, &opts)
    }

    /// [`step_streamed`](Self::step_streamed) with explicit
    /// [`StreamedStepOptions`] (frozen-gating baselines, custom
    /// balance-loss weights).
    pub fn step_streamed_with(
        &self,
        sched: &Scheduler,
        state: &mut StreamedTrainState,
        xs: &[TensorF],
        targets: &[TensorF],
        rng: Option<&mut Rng>,
        opts: &StreamedStepOptions,
    ) -> Result<StreamedStepMetrics> {
        if xs.len() != targets.len() {
            bail!("{} replica inputs but {} targets", xs.len(), targets.len());
        }
        if xs.is_empty() {
            bail!("no replica inputs");
        }
        for (x, t) in xs.iter().zip(targets.iter()) {
            if x.shape != t.shape {
                bail!("input shape {:?} vs target {:?}", x.shape, t.shape);
            }
        }
        let t0 = Instant::now();
        let refs: Vec<&TensorF> = xs.iter().collect();
        let s =
            sched.execute_streamed(&state.router, &refs, &state.weights, rng)?;

        let (loss, grads) = streamed_backward(
            &state.router,
            &state.weights,
            &refs,
            targets,
            &s,
            opts.w_importance,
            opts.w_load,
            opts.train_gating,
        )?;

        // Adam updates (shared optimizer module); every tensor advances
        // its own bias-correction clock, so tensors whose gradients
        // start mid-run (gating un-frozen after baseline steps, a noise
        // net that only sees noisy steps, fresh moments after a
        // pre-Adam-checkpoint resume) warm up correctly instead of
        // inheriting a stale clock and over-scaling their first updates
        let p = AdamParams::default();
        for ((w, (g_in, g_out)), (st_in, st_out)) in state
            .weights
            .iter_mut()
            .zip(grads.experts.iter())
            .zip(state.opt.experts.iter_mut())
        {
            st_in.update(&p, opts.lr, &mut w.w_in, g_in);
            st_out.update(&p, opts.lr, &mut w.w_out, g_out);
        }
        if let Some(g) = &grads.gate {
            state.opt.update_gating(&p, opts.lr, &mut state.router, g)?;
        }

        let metrics = StreamedStepMetrics {
            step: state.step,
            loss: loss.task,
            balance_loss: loss.balance,
            grad_norm: loss.grad_norm,
            cv_importance: loss.cv_importance,
            cv_load: loss.cv_load,
            step_time: t0.elapsed().as_secs_f64(),
            stats: s.stats,
        };
        state.step += 1;
        Ok(metrics)
    }

    /// Train for `steps` steps from the batcher, returning per-step
    /// metrics; `log_every` prints progress lines.
    pub fn run(&self, state: &mut TrainState, batcher: &mut Batcher,
               steps: u64, log_every: u64) -> Result<Vec<StepMetrics>> {
        let mut out = Vec::with_capacity(steps as usize);
        for i in 0..steps {
            let tokens = batcher.next_batch();
            let m = self.step(state, &tokens)?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} nll {:.4} ppl {:.1} \
                     cv_imp {:.3} cv_load {:.3} drop {:.3} ({:.0} tok/s)",
                    self.entry.config.name,
                    m.step,
                    m.loss,
                    m.nll,
                    m.nll.exp(),
                    m.cv_importance,
                    m.cv_load,
                    m.dropped_frac,
                    self.tokens_per_step as f64 / m.step_time
                );
            }
            out.push(m);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ExpertBackend;
    use crate::coordinator::ShardLayout;

    #[test]
    fn artifact_methods_error_cleanly_without_artifacts() {
        let trainer = Trainer::native(ModelConfig::native_moe(
            "native-tiny", 4, 4, 2, 8, 2, 4,
        ));
        let err = trainer.init(0).unwrap_err().to_string();
        assert!(err.contains("without artifacts"), "{err}");
        assert_eq!(trainer.tokens_per_step, 8);
    }

    #[test]
    fn streamed_training_reduces_loss_without_artifacts() {
        // the acceptance path: Trainer::step_streamed end to end on a
        // bare checkout — forward on the dependency-driven streamed
        // engine, native backward through combine + experts + gating,
        // Adam.  Deterministic (eval routing, fixed batch), so the loss
        // trajectory is exactly reproducible.
        let (d, h, n, k) = (8, 16, 6, 2);
        let trainer =
            Trainer::native(ModelConfig::native_moe("native-moe", d, n, k, h, 2, 16));
        let mut state = trainer.init_streamed(3);
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(11);
        let rows = 24;
        let mk = |rng: &mut Rng, s: f32| {
            (0..2)
                .map(|_| {
                    TensorF::new(
                        vec![rows, d],
                        (0..rows * d).map(|_| rng.normal_f32() * s).collect(),
                    )
                })
                .collect::<Vec<_>>()
        };
        let xs = mk(&mut rng, 1.0);
        let targets = mk(&mut rng, 0.5);
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for i in 0..40 {
            let m = trainer
                .step_streamed(&sched, &mut state, &xs, &targets, 0.01, None)
                .unwrap();
            assert!(m.loss.is_finite(), "step {i}: loss diverged");
            assert!(m.balance_loss.is_finite());
            assert!(m.grad_norm.is_finite());
            assert!((0.0..=1.0).contains(&m.stats.combine_overlap_ratio()));
            if i == 0 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert_eq!(state.step, 40);
        assert!(
            last < first,
            "Adam on the streamed step must descend: {first} -> {last}"
        );
        // telemetry flows through from the engine
        assert_eq!(state.weights.len(), n);
        assert!(state.router.n_experts == n);
        // gating actually moved (it is no longer frozen) and its Adam
        // moments are live
        assert!(state.opt.w_g.m.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn frozen_gating_option_leaves_router_untouched() {
        let (d, h, n, k) = (6, 10, 4, 2);
        let trainer = Trainer::native(ModelConfig::native_moe(
            "native-frozen", d, n, k, h, 1, 8,
        ));
        let mut state = trainer.init_streamed(7);
        let w_g_before = state.router.w_g.clone();
        let w_n_before = state.router.w_noise.clone();
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(5);
        let xs = vec![TensorF::new(
            vec![8, d],
            (0..8 * d).map(|_| rng.normal_f32()).collect(),
        )];
        let targets = vec![TensorF::new(
            vec![8, d],
            (0..8 * d).map(|_| rng.normal_f32() * 0.5).collect(),
        )];
        let opts = StreamedStepOptions {
            lr: 0.01,
            train_gating: false,
            w_importance: 0.1,
            w_load: 0.1,
        };
        let mut nrng = rng.fold_in(1);
        let m = trainer
            .step_streamed_with(
                &sched, &mut state, &xs, &targets, Some(&mut nrng), &opts,
            )
            .unwrap();
        assert_eq!(state.router.w_g, w_g_before, "frozen gating moved");
        assert_eq!(state.router.w_noise, w_n_before);
        assert!(m.balance_loss.is_finite());
        // experts still train
        assert!(state.opt.experts[0].0.m.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn streamed_step_validates_shapes() {
        let trainer = Trainer::native(ModelConfig::native_moe(
            "native-bad", 4, 4, 1, 8, 1, 4,
        ));
        let mut state = trainer.init_streamed(0);
        let sched = Scheduler::new(ShardLayout::new(1, 4), ExpertBackend::Native);
        let xs = vec![TensorF::zeros(vec![3, 4])];
        let bad_targets = vec![TensorF::zeros(vec![2, 4])];
        assert!(trainer
            .step_streamed(&sched, &mut state, &xs, &bad_targets, 0.1, None)
            .is_err());
        assert!(trainer
            .step_streamed(&sched, &mut state, &xs, &[], 0.1, None)
            .is_err());
    }
}
