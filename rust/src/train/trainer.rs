//! Trainer: drives `step_<cfg>.hlo.txt` (params, m, v, tokens, step) ->
//! (params', m', v', metrics) and `eval_<cfg>.hlo.txt`.
//!
//! The LR schedule, optimizer, dropout and gating noise all live INSIDE
//! the artifact (keyed by the step counter input), so the artifact loop
//! is pure data movement: batch in, metrics out.
//!
//! # Artifact-free streamed training
//!
//! Training no longer *requires* the artifact path:
//! [`Trainer::native`] builds a trainer from a bare [`ModelConfig`]
//! (no manifest, no PJRT), and [`Trainer::step_streamed`] runs the MoE
//! sublayer forward on [`Scheduler::execute_streamed`] — the
//! dependency-driven pipelined engine — then backpropagates through the
//! gate-weighted combine (eq 1) and the expert FFNs in native rust and
//! applies SGD to the expert weights.  Gating parameters are frozen
//! within the step (the balance statistics are reported, not trained);
//! the loss is mean squared error against caller-provided targets, the
//! regression framing the sublayer admits without the LSTM stack.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::scheduler::ExpertWeights;
use crate::coordinator::{Dispatcher, Router, Scheduler, StepStats};
use crate::data::Batcher;
use crate::gating::noisy_topk::{cv_squared, matmul};
use crate::metrics::perplexity;
use crate::runtime::{
    ConfigEntry, Engine, ExecPhases, Executable, Host, Manifest, ModelConfig,
    TensorF, TensorI,
};
use crate::util::rng::Rng;

/// Decoded metrics vector of one step (names from the manifest).
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub nll: f64,
    pub balance_loss: f64,
    pub cv_importance: f64,
    pub cv_load: f64,
    pub max_over_mean_load: f64,
    pub dropped_frac: f64,
    pub grad_norm: f64,
    pub lr: f64,
    pub step_time: f64,
    /// stage-in / execute / stage-out breakdown of the step artifact
    /// call, mirroring the coordinator's gather/compute/combine split
    pub phases: ExecPhases,
}

impl StepMetrics {
    fn from_vec(step: u64, names: &[String], v: &[f32], dt: f64,
                phases: ExecPhases) -> Self {
        let get = |n: &str| {
            names
                .iter()
                .position(|x| x == n)
                .map(|i| v[i] as f64)
                .unwrap_or(f64::NAN)
        };
        StepMetrics {
            step,
            loss: get("loss"),
            nll: get("nll"),
            balance_loss: get("balance_loss"),
            cv_importance: get("cv_importance"),
            cv_load: get("cv_load"),
            max_over_mean_load: get("max_over_mean_load"),
            dropped_frac: get("dropped_frac"),
            grad_norm: get("grad_norm"),
            lr: get("lr"),
            step_time: dt,
            phases,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub nll_sum: f64,
    pub tokens: f64,
}

impl EvalResult {
    pub fn perplexity(&self) -> f64 {
        perplexity(self.nll_sum, self.tokens)
    }
}

/// Model + optimizer state living on the rust side between steps.
pub struct TrainState {
    pub params: TensorF,
    pub m: TensorF,
    pub v: TensorF,
    pub step: u64,
}

/// Model + optimizer state of the artifact-free streamed path: the MoE
/// sublayer's router and expert weights, trained natively.
pub struct StreamedTrainState {
    pub router: Router,
    pub weights: Vec<ExpertWeights>,
    pub step: u64,
}

/// Metrics of one artifact-free streamed training step.
#[derive(Clone, Debug)]
pub struct StreamedStepMetrics {
    pub step: u64,
    /// mean squared error over every output element
    pub loss: f64,
    /// l2 norm of the expert-weight gradients this step
    pub grad_norm: f64,
    /// CV(Importance) over the step's merged routing decisions (eq 6)
    pub cv_importance: f64,
    /// CV(Load) over the step's merged routing decisions (eq 8–10)
    pub cv_load: f64,
    pub step_time: f64,
    /// full engine telemetry of the forward step (overlap ratio et al.
    /// via [`StepStats::combine_overlap_ratio`])
    pub stats: StepStats,
}

pub struct Trainer {
    pub entry: ConfigEntry,
    /// `None` on [`native`](Self::native) trainers (bare checkout, no
    /// artifacts) — the artifact methods error cleanly, the streamed
    /// path works
    step_exe: Option<Arc<Executable>>,
    eval_exe: Option<Arc<Executable>>,
    init_exe: Option<Arc<Executable>>,
    pub tokens_per_step: u64,
}

impl Trainer {
    pub fn new(engine: &Engine, manifest: &Manifest, cfg: &str) -> Result<Self> {
        let entry = manifest.config(cfg)?.clone();
        Ok(Trainer {
            step_exe: Some(engine.load(manifest, cfg, "step")?),
            eval_exe: Some(engine.load(manifest, cfg, "eval")?),
            init_exe: Some(engine.load(manifest, cfg, "init")?),
            tokens_per_step: (entry.config.batch * entry.config.seq_len) as u64,
            entry,
        })
    }

    /// Artifact-free construction from a bare [`ModelConfig`] — no
    /// manifest, no PJRT, works on a fresh offline checkout.  Only the
    /// streamed path ([`init_streamed`](Self::init_streamed) /
    /// [`step_streamed`](Self::step_streamed)) is available.
    pub fn native(config: ModelConfig) -> Trainer {
        let tokens_per_step = (config.batch * config.seq_len) as u64;
        Trainer {
            entry: ConfigEntry {
                config,
                metric_names: Vec::new(),
                params: Vec::new(),
                param_size: 0,
                opt_sizes: (0, 0),
                decode_batch: 0,
                n_lstm: 0,
                artifacts: BTreeMap::new(),
            },
            step_exe: None,
            eval_exe: None,
            init_exe: None,
            tokens_per_step,
        }
    }

    fn artifact(exe: &Option<Arc<Executable>>, kind: &str)
        -> Result<Arc<Executable>> {
        exe.clone().ok_or_else(|| {
            anyhow!(
                "trainer was built without artifacts ({kind} unavailable); \
                 use the streamed path (init_streamed / step_streamed)"
            )
        })
    }

    /// Initialize parameters via the init artifact (gating nets start at
    /// zero per Appendix A).
    pub fn init(&self, seed: i32) -> Result<TrainState> {
        let outs = Self::artifact(&self.init_exe, "init")?
            .run(&[Host::I32(TensorI::scalar(seed))])
            .context("running init artifact")?;
        let mut it = outs.into_iter();
        Ok(TrainState {
            params: it.next().unwrap().into_f32()?,
            m: it.next().unwrap().into_f32()?,
            v: it.next().unwrap().into_f32()?,
            step: 0,
        })
    }

    /// One training step; consumes and replaces the state buffers.
    pub fn step(&self, state: &mut TrainState, tokens: &TensorI)
        -> Result<StepMetrics> {
        let t0 = Instant::now();
        let (outs, phases) = Self::artifact(&self.step_exe, "step")?.run_phased(&[
            Host::F32(std::mem::replace(&mut state.params, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.m, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.v, TensorF::zeros(vec![0]))),
            Host::I32(tokens.clone()),
            Host::I32(TensorI::scalar(state.step as i32)),
        ])?;
        let mut it = outs.into_iter();
        state.params = it.next().unwrap().into_f32()?;
        state.m = it.next().unwrap().into_f32()?;
        state.v = it.next().unwrap().into_f32()?;
        let metrics = it.next().unwrap().into_f32()?;
        let sm = StepMetrics::from_vec(
            state.step,
            &self.entry.metric_names,
            &metrics.data,
            t0.elapsed().as_secs_f64(),
            phases,
        );
        state.step += 1;
        Ok(sm)
    }

    /// Run `n_batches` of held-out data through the eval artifact.
    pub fn evaluate(&self, state: &TrainState, batcher: &mut Batcher,
                    n_batches: usize) -> Result<EvalResult> {
        let eval_exe = Self::artifact(&self.eval_exe, "eval")?;
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for _ in 0..n_batches {
            let tokens = batcher.next_batch();
            let outs = eval_exe.run(&[params.clone(), Host::I32(tokens)])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Evaluate over explicit token tensors (translation path).
    pub fn evaluate_tokens(&self, state: &TrainState, batches: &[TensorI])
        -> Result<EvalResult> {
        let eval_exe = Self::artifact(&self.eval_exe, "eval")?;
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for tokens in batches {
            let outs =
                eval_exe.run(&[params.clone(), Host::I32(tokens.clone())])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Initialize the artifact-free streamed state from the config
    /// dims: small random expert weights, and gating weights perturbed
    /// slightly away from the Appendix-A zero init so routing is
    /// non-degenerate from step 0 (the artifact's training ramp does
    /// this within a few steps).
    pub fn init_streamed(&self, seed: u64) -> StreamedTrainState {
        let c = &self.entry.config;
        let (d, h, n, k) = (c.d_model, c.expert_hidden, c.n_experts, c.k);
        let mut rng = Rng::new(seed);
        let scale = (2.0 / d.max(1) as f32).sqrt() * 0.5;
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * scale).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * scale).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d,
            n,
            k,
            (0..d * n).map(|_| rng.normal_f32() * 0.1).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.1).collect()),
        );
        StreamedTrainState { router, weights, step: 0 }
    }

    /// One artifact-free training step of the MoE sublayer (module
    /// docs): forward on [`Scheduler::execute_streamed`], MSE loss
    /// against `targets`, exact backprop through the gate-weighted
    /// combine and the expert FFNs, SGD update of the expert weights.
    /// `rng` draws the eq-4 routing noise (`None` = deterministic
    /// routing).  Runs end to end on a bare offline checkout.
    pub fn step_streamed(
        &self,
        sched: &Scheduler,
        state: &mut StreamedTrainState,
        xs: &[TensorF],
        targets: &[TensorF],
        lr: f32,
        rng: Option<&mut Rng>,
    ) -> Result<StreamedStepMetrics> {
        let c = &self.entry.config;
        let d = c.d_model;
        if xs.len() != targets.len() {
            bail!("{} replica inputs but {} targets", xs.len(), targets.len());
        }
        for (x, t) in xs.iter().zip(targets.iter()) {
            if x.shape != t.shape {
                bail!("input shape {:?} vs target {:?}", x.shape, t.shape);
            }
        }
        let t0 = Instant::now();
        let refs: Vec<&TensorF> = xs.iter().collect();
        let s = sched.execute_streamed(&state.router, &refs, &state.weights, rng)?;

        // MSE loss and its gradient wrt the combined outputs
        let n_el: usize = s.outs.iter().map(|t| t.data.len()).sum();
        let scale = 2.0 / n_el.max(1) as f32;
        let mut loss = 0.0f64;
        let mut grads_y: Vec<Vec<f32>> = Vec::with_capacity(s.outs.len());
        for (y, t) in s.outs.iter().zip(targets.iter()) {
            let g = y
                .data
                .iter()
                .zip(t.data.iter())
                .map(|(a, b)| {
                    let e = a - b;
                    loss += (e * e) as f64;
                    scale * e
                })
                .collect();
            grads_y.push(g);
        }
        loss /= n_el.max(1) as f64;

        // backprop per expert: dL/d(expert row) = gate · dL/dy[token]
        // (eq 1 is linear in the expert outputs), then the standard
        // two-layer relu-FFN backward; gather reuses the step's plan
        let mut grad_sq = 0.0f64;
        for (e, w) in state.weights.iter_mut().enumerate() {
            let batch = &s.plan.per_expert[e];
            let rows = batch.tokens.len();
            if rows == 0 {
                continue;
            }
            let h = w.hidden;
            let x = Dispatcher::gather(&s.plan, e, &refs);
            let mut gout = vec![0f32; rows * d];
            for (slot, (addr, gate)) in
                batch.tokens.iter().zip(batch.gates.iter()).enumerate()
            {
                let gy = &grads_y[addr.replica][addr.row * d..(addr.row + 1) * d];
                for (o, g) in gout[slot * d..(slot + 1) * d].iter_mut().zip(gy) {
                    *o = gate * g;
                }
            }
            // recompute hidden activations (cheaper than caching them
            // across the engine boundary)
            let mut hid = vec![0f32; rows * h];
            matmul(&x.data, &w.w_in, &mut hid, rows, d, h);
            for v in hid.iter_mut() {
                *v = v.max(0.0);
            }
            // dW_out = hiddenᵀ · gout
            let mut d_wout = vec![0f32; h * d];
            matmul_tn(&hid, &gout, &mut d_wout, rows, h, d);
            // d_hidden = gout · W_outᵀ, masked by the relu
            let mut d_hid = vec![0f32; rows * h];
            matmul_nt(&gout, &w.w_out, &mut d_hid, rows, h, d);
            for (dh, hv) in d_hid.iter_mut().zip(hid.iter()) {
                if *hv <= 0.0 {
                    *dh = 0.0;
                }
            }
            // dW_in = xᵀ · d_hidden
            let mut d_win = vec![0f32; d * h];
            matmul_tn(&x.data, &d_hid, &mut d_win, rows, d, h);

            for g in d_wout.iter().chain(d_win.iter()) {
                grad_sq += (*g as f64) * (*g as f64);
            }
            for (wv, g) in w.w_out.iter_mut().zip(d_wout.iter()) {
                *wv -= lr * g;
            }
            for (wv, g) in w.w_in.iter_mut().zip(d_win.iter()) {
                *wv -= lr * g;
            }
        }

        // balance telemetry over the merged decisions (reported, not
        // trained — gating is frozen within the step)
        let n = c.n_experts;
        let mut imp = vec![0f32; n];
        let mut load = vec![0f32; n];
        for dec in &s.decisions {
            for (a, v) in imp.iter_mut().zip(dec.importance.iter()) {
                *a += v;
            }
            for (a, v) in load.iter_mut().zip(dec.load.iter()) {
                *a += v;
            }
        }
        let metrics = StreamedStepMetrics {
            step: state.step,
            loss,
            grad_norm: grad_sq.sqrt(),
            cv_importance: (cv_squared(&imp) as f64).sqrt(),
            cv_load: (cv_squared(&load) as f64).sqrt(),
            step_time: t0.elapsed().as_secs_f64(),
            stats: s.stats,
        };
        state.step += 1;
        Ok(metrics)
    }

    /// Train for `steps` steps from the batcher, returning per-step
    /// metrics; `log_every` prints progress lines.
    pub fn run(&self, state: &mut TrainState, batcher: &mut Batcher,
               steps: u64, log_every: u64) -> Result<Vec<StepMetrics>> {
        let mut out = Vec::with_capacity(steps as usize);
        for i in 0..steps {
            let tokens = batcher.next_batch();
            let m = self.step(state, &tokens)?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} nll {:.4} ppl {:.1} \
                     cv_imp {:.3} cv_load {:.3} drop {:.3} ({:.0} tok/s)",
                    self.entry.config.name,
                    m.step,
                    m.loss,
                    m.nll,
                    m.nll.exp(),
                    m.cv_importance,
                    m.cv_load,
                    m.dropped_frac,
                    self.tokens_per_step as f64 / m.step_time
                );
            }
            out.push(m);
        }
        Ok(out)
    }
}

/// `out (k, n) = aᵀ · b` for row-major `a (m, k)`, `b (m, n)`.  Walks
/// `a`/`b` row by row so the inner loops stream contiguous memory.
fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (av, orow) in arow.iter().zip(out.chunks_mut(n)) {
            for (o, bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out (m, n) = a · bᵀ` for row-major `a (m, k)`, `b (n, k)`.
fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for (arow, orow) in a.chunks(k).zip(out.chunks_mut(n)) {
        for (bv, o) in b.chunks(k).zip(orow.iter_mut()) {
            *o = arow.iter().zip(bv.iter()).map(|(x, y)| x * y).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ExpertBackend;
    use crate::coordinator::ShardLayout;
    use crate::util::prop;

    #[test]
    fn transpose_matmuls_match_naive() {
        prop::forall("tn/nt matmuls", |rng| {
            let (m, k, n) = (
                prop::dim(rng, 1, 6),
                prop::dim(rng, 1, 5),
                prop::dim(rng, 1, 4),
            );
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, m * n, 1.0);
            let mut got = vec![0f32; k * n];
            matmul_tn(&a, &b, &mut got, m, k, n);
            for p in 0..k {
                for q in 0..n {
                    let want: f32 =
                        (0..m).map(|i| a[i * k + p] * b[i * n + q]).sum();
                    assert!((got[p * n + q] - want).abs() < 1e-4);
                }
            }
            let c = prop::vec_f32(rng, n * k, 1.0);
            let mut got = vec![0f32; m * n];
            matmul_nt(&a, &c, &mut got, m, n, k);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 =
                        (0..k).map(|l| a[i * k + l] * c[j * k + l]).sum();
                    assert!((got[i * n + j] - want).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn artifact_methods_error_cleanly_without_artifacts() {
        let trainer = Trainer::native(ModelConfig::native_moe(
            "native-tiny", 4, 4, 2, 8, 2, 4,
        ));
        let err = trainer.init(0).unwrap_err().to_string();
        assert!(err.contains("without artifacts"), "{err}");
        assert_eq!(trainer.tokens_per_step, 8);
    }

    #[test]
    fn streamed_training_reduces_loss_without_artifacts() {
        // the acceptance path: Trainer::step_streamed end to end on a
        // bare checkout — forward on the dependency-driven streamed
        // engine, native backward, SGD.  Deterministic (eval routing,
        // fixed batch), so the loss trajectory is exactly reproducible.
        let (d, h, n, k) = (8, 16, 6, 2);
        let trainer =
            Trainer::native(ModelConfig::native_moe("native-moe", d, n, k, h, 2, 16));
        let mut state = trainer.init_streamed(3);
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(11);
        let rows = 24;
        let mk = |rng: &mut Rng, s: f32| {
            (0..2)
                .map(|_| {
                    TensorF::new(
                        vec![rows, d],
                        (0..rows * d).map(|_| rng.normal_f32() * s).collect(),
                    )
                })
                .collect::<Vec<_>>()
        };
        let xs = mk(&mut rng, 1.0);
        let targets = mk(&mut rng, 0.5);
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for i in 0..40 {
            let m = trainer
                .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
                .unwrap();
            assert!(m.loss.is_finite(), "step {i}: loss diverged");
            assert!(m.grad_norm.is_finite());
            assert!((0.0..=1.0).contains(&m.stats.combine_overlap_ratio()));
            if i == 0 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert_eq!(state.step, 40);
        assert!(
            last < first,
            "SGD on the streamed step must descend: {first} -> {last}"
        );
        // telemetry flows through from the engine
        assert_eq!(state.weights.len(), n);
        assert!(state.router.n_experts == n);
    }

    #[test]
    fn streamed_step_validates_shapes() {
        let trainer = Trainer::native(ModelConfig::native_moe(
            "native-bad", 4, 4, 1, 8, 1, 4,
        ));
        let mut state = trainer.init_streamed(0);
        let sched = Scheduler::new(ShardLayout::new(1, 4), ExpertBackend::Native);
        let xs = vec![TensorF::zeros(vec![3, 4])];
        let bad_targets = vec![TensorF::zeros(vec![2, 4])];
        assert!(trainer
            .step_streamed(&sched, &mut state, &xs, &bad_targets, 0.1, None)
            .is_err());
        assert!(trainer
            .step_streamed(&sched, &mut state, &xs, &[], 0.1, None)
            .is_err());
    }
}
