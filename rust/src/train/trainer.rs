//! Trainer: drives `step_<cfg>.hlo.txt` (params, m, v, tokens, step) ->
//! (params', m', v', metrics) and `eval_<cfg>.hlo.txt`.
//!
//! The LR schedule, optimizer, dropout and gating noise all live INSIDE
//! the artifact (keyed by the step counter input), so the rust loop is
//! pure data movement: batch in, metrics out.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::Batcher;
use crate::metrics::perplexity;
use crate::runtime::{
    ConfigEntry, Engine, ExecPhases, Executable, Host, Manifest, TensorF,
    TensorI,
};

/// Decoded metrics vector of one step (names from the manifest).
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub nll: f64,
    pub balance_loss: f64,
    pub cv_importance: f64,
    pub cv_load: f64,
    pub max_over_mean_load: f64,
    pub dropped_frac: f64,
    pub grad_norm: f64,
    pub lr: f64,
    pub step_time: f64,
    /// stage-in / execute / stage-out breakdown of the step artifact
    /// call, mirroring the coordinator's gather/compute/combine split
    pub phases: ExecPhases,
}

impl StepMetrics {
    fn from_vec(step: u64, names: &[String], v: &[f32], dt: f64,
                phases: ExecPhases) -> Self {
        let get = |n: &str| {
            names
                .iter()
                .position(|x| x == n)
                .map(|i| v[i] as f64)
                .unwrap_or(f64::NAN)
        };
        StepMetrics {
            step,
            loss: get("loss"),
            nll: get("nll"),
            balance_loss: get("balance_loss"),
            cv_importance: get("cv_importance"),
            cv_load: get("cv_load"),
            max_over_mean_load: get("max_over_mean_load"),
            dropped_frac: get("dropped_frac"),
            grad_norm: get("grad_norm"),
            lr: get("lr"),
            step_time: dt,
            phases,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub nll_sum: f64,
    pub tokens: f64,
}

impl EvalResult {
    pub fn perplexity(&self) -> f64 {
        perplexity(self.nll_sum, self.tokens)
    }
}

/// Model + optimizer state living on the rust side between steps.
pub struct TrainState {
    pub params: TensorF,
    pub m: TensorF,
    pub v: TensorF,
    pub step: u64,
}

pub struct Trainer {
    pub entry: ConfigEntry,
    step_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    init_exe: Arc<Executable>,
    pub tokens_per_step: u64,
}

impl Trainer {
    pub fn new(engine: &Engine, manifest: &Manifest, cfg: &str) -> Result<Self> {
        let entry = manifest.config(cfg)?.clone();
        Ok(Trainer {
            step_exe: engine.load(manifest, cfg, "step")?,
            eval_exe: engine.load(manifest, cfg, "eval")?,
            init_exe: engine.load(manifest, cfg, "init")?,
            tokens_per_step: (entry.config.batch * entry.config.seq_len) as u64,
            entry,
        })
    }

    /// Initialize parameters via the init artifact (gating nets start at
    /// zero per Appendix A).
    pub fn init(&self, seed: i32) -> Result<TrainState> {
        let outs = self
            .init_exe
            .run(&[Host::I32(TensorI::scalar(seed))])
            .context("running init artifact")?;
        let mut it = outs.into_iter();
        Ok(TrainState {
            params: it.next().unwrap().into_f32()?,
            m: it.next().unwrap().into_f32()?,
            v: it.next().unwrap().into_f32()?,
            step: 0,
        })
    }

    /// One training step; consumes and replaces the state buffers.
    pub fn step(&self, state: &mut TrainState, tokens: &TensorI)
        -> Result<StepMetrics> {
        let t0 = Instant::now();
        let (outs, phases) = self.step_exe.run_phased(&[
            Host::F32(std::mem::replace(&mut state.params, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.m, TensorF::zeros(vec![0]))),
            Host::F32(std::mem::replace(&mut state.v, TensorF::zeros(vec![0]))),
            Host::I32(tokens.clone()),
            Host::I32(TensorI::scalar(state.step as i32)),
        ])?;
        let mut it = outs.into_iter();
        state.params = it.next().unwrap().into_f32()?;
        state.m = it.next().unwrap().into_f32()?;
        state.v = it.next().unwrap().into_f32()?;
        let metrics = it.next().unwrap().into_f32()?;
        let sm = StepMetrics::from_vec(
            state.step,
            &self.entry.metric_names,
            &metrics.data,
            t0.elapsed().as_secs_f64(),
            phases,
        );
        state.step += 1;
        Ok(sm)
    }

    /// Run `n_batches` of held-out data through the eval artifact.
    pub fn evaluate(&self, state: &TrainState, batcher: &mut Batcher,
                    n_batches: usize) -> Result<EvalResult> {
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for _ in 0..n_batches {
            let tokens = batcher.next_batch();
            let outs = self.eval_exe.run(&[params.clone(), Host::I32(tokens)])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Evaluate over explicit token tensors (translation path).
    pub fn evaluate_tokens(&self, state: &TrainState, batches: &[TensorI])
        -> Result<EvalResult> {
        let mut total = EvalResult { nll_sum: 0.0, tokens: 0.0 };
        let params = Host::F32(state.params.clone());
        for tokens in batches {
            let outs =
                self.eval_exe.run(&[params.clone(), Host::I32(tokens.clone())])?;
            let v = outs[0].as_f32()?;
            total.nll_sum += v.data[0] as f64;
            total.tokens += v.data[1] as f64;
        }
        Ok(total)
    }

    /// Train for `steps` steps from the batcher, returning per-step
    /// metrics; `log_every` prints progress lines.
    pub fn run(&self, state: &mut TrainState, batcher: &mut Batcher,
               steps: u64, log_every: u64) -> Result<Vec<StepMetrics>> {
        let mut out = Vec::with_capacity(steps as usize);
        for i in 0..steps {
            let tokens = batcher.next_batch();
            let m = self.step(state, &tokens)?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} nll {:.4} ppl {:.1} \
                     cv_imp {:.3} cv_load {:.3} drop {:.3} ({:.0} tok/s)",
                    self.entry.config.name,
                    m.step,
                    m.loss,
                    m.nll,
                    m.nll.exp(),
                    m.cv_importance,
                    m.cv_load,
                    m.dropped_frac,
                    self.tokens_per_step as f64 / m.step_time
                );
            }
            out.push(m);
        }
        Ok(out)
    }
}
