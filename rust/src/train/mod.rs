//! Training: the step loop over the AOT'd train-step artifact, evaluation,
//! and checkpointing.  Python never runs here — the artifact carries the
//! whole fwd/bwd/update graph and the trainer just round-trips the flat
//! parameter and optimizer buffers.
//!
//! The artifact is no longer a hard requirement: [`Trainer::native`] /
//! [`Trainer::step_streamed`] train the MoE sublayer on the
//! dependency-driven streamed engine with a native backward pass, on a
//! bare offline checkout.

pub mod checkpoint;
pub mod trainer;

pub use trainer::{
    EvalResult, StepMetrics, StreamedStepMetrics, StreamedTrainState,
    TrainState, Trainer,
};
