//! Training: the step loop over the AOT'd train-step artifact, evaluation,
//! and checkpointing.  Python never runs here — the artifact carries the
//! whole fwd/bwd/update graph and the trainer just round-trips the flat
//! parameter and optimizer buffers.
//!
//! The artifact is no longer a hard requirement: [`Trainer::native`] /
//! [`Trainer::step_streamed`] train the MoE sublayer on the
//! dependency-driven streamed engine with a native backward pass —
//! expert FFNs, combine, *and* the gating network with its eq-6/eq-8
//! balance losses ([`trainer::streamed_backward`]) — updated by the
//! shared Adam optimizer ([`optimizer`]), on a bare offline checkout.

pub mod checkpoint;
pub mod optimizer;
pub mod trainer;

pub use optimizer::{AdamParams, AdamState, StreamedOptState};
pub use trainer::{
    streamed_backward, EvalResult, StepMetrics, StreamedGrads, StreamedLoss,
    StreamedStepMetrics, StreamedStepOptions, StreamedTrainState, TrainState,
    Trainer,
};
