//! Training: the step loop over the AOT'd train-step artifact, evaluation,
//! and checkpointing.  Python never runs here — the artifact carries the
//! whole fwd/bwd/update graph and the trainer just round-trips the flat
//! parameter and optimizer buffers.

pub mod checkpoint;
pub mod trainer;

pub use trainer::{EvalResult, StepMetrics, TrainState, Trainer};
