//! Checkpoints: flat param/opt buffers with a small self-describing
//! header.  Format (little-endian):
//!
//! ```text
//!   magic  "MOECKPT1"            8 bytes
//!   step   u64
//!   name   u32 len + utf-8       config name (sanity-checked on load)
//!   3 sections, each: u64 len + len * f32   (params, m, v)
//! ```
//!
//! The artifact-free streamed trainer state ([`StreamedTrainState`]) is
//! stored in the same container via [`save_streamed`] /
//! [`load_streamed`]: router and expert weights are flattened into the
//! `params` section in a fixed order (`w_g | w_noise? | per expert
//! w_in, w_out`), and the per-tensor Adam moments
//! ([`crate::train::optimizer::StreamedOptState`]) fill the `m` / `v`
//! sections in the same order — so a resumed run continues
//! bit-identically, optimizer momentum included.  Whether the router
//! had a noise net is recovered from the section length, so both
//! shapes round-trip; empty optimizer sections (pre-Adam checkpoints)
//! resume with fresh moments.  This is also how the serving runtime
//! ([`crate::serve`]) freezes gating from a training run.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::scheduler::ExpertWeights;
use crate::coordinator::Router;
use crate::runtime::{ModelConfig, TensorF};
use crate::train::trainer::{StreamedTrainState, TrainState};

const MAGIC: &[u8; 8] = b"MOECKPT1";

/// Trailer appended by [`save_streamed`] carrying the per-tensor Adam
/// bias-correction clocks
/// ([`AdamState::t`](crate::train::optimizer::AdamState)), which can
/// differ from the trainer step — and from each other — when a
/// pre-Adam checkpoint was resumed (fresh moments restart at 0) or a
/// tensor only received gradients on some steps (a noise net under
/// deterministic routing, gating un-frozen mid-run).  Layout, at the
/// very end of the file so old readers never see it:
///
/// ```text
///   clocks  count * u64    (flatten order: w_g | w_noise? | experts)
///   count   u64
///   tag     "ADAMCLK1"     8 bytes
/// ```
///
/// Old files simply end after the `v` section; [`load_streamed`]
/// probes the tag from the end and falls back to the trainer step,
/// which coincides with the clocks for runs trained from step 0 under
/// Adam with noise on.
const CLOCK_TAG: &[u8; 8] = b"ADAMCLK1";

pub fn save(path: &Path, cfg_name: &str, state: &TrainState) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(cfg_name.len() as u32).to_le_bytes())?;
    f.write_all(cfg_name.as_bytes())?;
    for t in [&state.params, &state.m, &state.v] {
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        for v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path, expect_cfg: &str) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a moe checkpoint");
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let name_len = u32::from_le_bytes(b4) as usize;
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("checkpoint name")?;
    if name != expect_cfg {
        bail!("checkpoint is for config '{name}', expected '{expect_cfg}'");
    }
    let read_section = |f: &mut dyn Read| -> Result<TensorF> {
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(TensorF::new(vec![len], data))
    };
    let params = read_section(&mut f)?;
    let m = read_section(&mut f)?;
    let v = read_section(&mut f)?;
    Ok(TrainState { params, m, v, step })
}

/// Save a [`StreamedTrainState`] (module docs: flattening order
/// `w_g | w_noise? | per expert w_in, w_out`, Adam moments in `m`/`v`).
/// Flat routers only: the format carries no hierarchical secondary
/// gates, and saving a truncated router would serve a different model
/// than was trained.  The check runs before any file is created, so a
/// rejected save leaves no partial file behind.
pub fn save_streamed(
    path: &Path,
    cfg_name: &str,
    state: &StreamedTrainState,
) -> Result<()> {
    if state.router.groups > 0
        || state.router.w_g_sec.is_some()
        || state.router.w_n_sec.is_some()
    {
        bail!(
            "streamed checkpoints support flat routers only (hierarchical \
             gating has secondary weights this format does not carry)"
        );
    }
    let mut flat = Vec::new();
    flat.extend_from_slice(&state.router.w_g);
    if let Some(wn) = &state.router.w_noise {
        flat.extend_from_slice(wn);
    }
    for w in &state.weights {
        flat.extend_from_slice(&w.w_in);
        flat.extend_from_slice(&w.w_out);
    }
    let (m, v) = state.opt.flatten();
    if m.len() != flat.len() || v.len() != flat.len() {
        bail!(
            "optimizer state holds {}/{} moment f32s but the model has {} \
             params — the state was assembled inconsistently; refusing to \
             write a checkpoint that cannot load",
            m.len(),
            v.len(),
            flat.len()
        );
    }
    let ts = TrainState {
        params: TensorF::new(vec![flat.len()], flat),
        m: TensorF::new(vec![m.len()], m),
        v: TensorF::new(vec![v.len()], v),
        step: state.step,
    };
    save(path, cfg_name, &ts)?;
    // trailer: the per-tensor Adam clocks, which diverge from the
    // trainer step after a pre-Adam-checkpoint resume and from each
    // other when a tensor skips steps (see CLOCK_TAG)
    let clocks = state.opt.clocks();
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(path)
        .with_context(|| format!("appending opt clocks to {path:?}"))?;
    for c in &clocks {
        f.write_all(&c.to_le_bytes())?;
    }
    f.write_all(&(clocks.len() as u64).to_le_bytes())?;
    f.write_all(CLOCK_TAG)?;
    Ok(())
}

/// Read the [`CLOCK_TAG`] trailer of a streamed checkpoint, if present
/// (files from before the trailer existed simply end after the `v`
/// section).
fn read_opt_clocks(path: &Path) -> Result<Option<Vec<u64>>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?;
    let size = f.metadata()?.len();
    if size < 16 {
        return Ok(None);
    }
    f.seek(SeekFrom::End(-16))?;
    let mut buf = [0u8; 16];
    f.read_exact(&mut buf)?;
    if &buf[8..] != CLOCK_TAG {
        return Ok(None);
    }
    let count = u64::from_le_bytes(buf[..8].try_into().unwrap());
    let bytes = count
        .checked_mul(8)
        .and_then(|b| b.checked_add(16))
        .filter(|total| *total <= size)
        .map(|total| total - 16)
        .ok_or_else(|| anyhow::anyhow!("{path:?}: corrupt clock trailer"))?;
    f.seek(SeekFrom::End(-16 - bytes as i64))?;
    let mut raw = vec![0u8; bytes as usize];
    f.read_exact(&mut raw)?;
    Ok(Some(
        raw.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect(),
    ))
}

/// Load a [`StreamedTrainState`] saved by [`save_streamed`].  `cfg`
/// supplies the dimensions the flat buffer is sliced by; the router's
/// noise net is detected from the section length.  Adam moments are
/// rebuilt from the `m`/`v` sections — empty sections (checkpoints
/// from before moments were carried) resume with fresh state.
pub fn load_streamed(
    path: &Path,
    expect_cfg: &str,
    cfg: &ModelConfig,
) -> Result<StreamedTrainState> {
    let ts = load(path, expect_cfg)?;
    let (d, h, n, k) = (cfg.d_model, cfg.expert_hidden, cfg.n_experts, cfg.k);
    let gate = d * n;
    let expert = 2 * d * h;
    let with_noise = 2 * gate + n * expert;
    let without = gate + n * expert;
    let flat = &ts.params.data;
    let has_noise = if flat.len() == with_noise {
        // ambiguous only if gate == 0, which new() forbids (d, n >= 1)
        true
    } else if flat.len() == without {
        false
    } else {
        bail!(
            "{path:?}: streamed checkpoint holds {} f32s but config \
             '{}' needs {} (with noise net) or {} (without)",
            flat.len(),
            cfg.name,
            with_noise,
            without
        );
    };
    let mut at = 0usize;
    let mut take = |len: usize| {
        let s = flat[at..at + len].to_vec();
        at += len;
        s
    };
    let w_g = take(gate);
    let w_noise = if has_noise { Some(take(gate)) } else { None };
    let weights = (0..n)
        .map(|_| ExpertWeights {
            w_in: take(d * h),
            w_out: take(h * d),
            d_model: d,
            hidden: h,
        })
        .collect();
    let mut opt = crate::train::optimizer::StreamedOptState::from_flat(
        &ts.m.data, &ts.v.data, d, h, n, has_noise, ts.step,
    )
    .with_context(|| format!("{path:?}: optimizer sections"))?;
    if !ts.m.data.is_empty() {
        if let Some(clocks) = read_opt_clocks(path)? {
            opt.set_clocks(&clocks)
                .with_context(|| format!("{path:?}: clock trailer"))?;
        }
    }
    Ok(StreamedTrainState {
        router: Router::flat_native(d, n, k, w_g, w_noise),
        weights,
        opt,
        step: ts.step,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let state = TrainState {
            params: TensorF::new(vec![5], vec![1.0, -2.0, 3.5, 0.0, 9.0]),
            m: TensorF::new(vec![2], vec![0.1, 0.2]),
            v: TensorF::new(vec![3], vec![7.0, 8.0, 9.0]),
            step: 42,
        };
        save(&path, "cfg-x", &state).unwrap();
        let back = load(&path, "cfg-x").unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.data, state.params.data);
        assert_eq!(back.m.data, state.m.data);
        assert_eq!(back.v.data, state.v.data);
    }

    #[test]
    fn wrong_config_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let state = TrainState {
            params: TensorF::zeros(vec![1]),
            m: TensorF::zeros(vec![1]),
            v: TensorF::zeros(vec![1]),
            step: 0,
        };
        save(&path, "cfg-a", &state).unwrap();
        assert!(load(&path, "cfg-b").is_err());
    }

    #[test]
    fn streamed_roundtrip_resumes_bit_identically() {
        use crate::coordinator::scheduler::ExpertBackend;
        use crate::coordinator::{Scheduler, ShardLayout};
        use crate::train::Trainer;
        use crate::util::rng::Rng;

        let (d, h, n, k) = (6, 10, 4, 2);
        let cfg = ModelConfig::native_moe("ckpt-stream", d, n, k, h, 2, 8);
        let trainer = Trainer::native(cfg.clone());
        let mut state = trainer.init_streamed(9);
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(31);
        let rows = 12;
        let mk = |rng: &mut Rng| {
            (0..2)
                .map(|_| {
                    TensorF::new(
                        vec![rows, d],
                        (0..rows * d).map(|_| rng.normal_f32()).collect(),
                    )
                })
                .collect::<Vec<_>>()
        };
        let xs = mk(&mut rng);
        let targets = mk(&mut rng);
        for _ in 0..5 {
            trainer
                .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
                .unwrap();
        }

        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("streamed.ckpt");
        save_streamed(&path, &cfg.name, &state).unwrap();
        let mut reloaded = load_streamed(&path, &cfg.name, &cfg).unwrap();
        assert_eq!(reloaded.step, state.step);
        assert_eq!(reloaded.router.w_g, state.router.w_g);
        assert_eq!(reloaded.router.w_noise, state.router.w_noise);
        for (a, b) in state.weights.iter().zip(reloaded.weights.iter()) {
            assert_eq!(a.w_in, b.w_in);
            assert_eq!(a.w_out, b.w_out);
        }
        // the round trip now carries the Adam moments, bit for bit —
        // after 5 steps they are non-trivial
        assert!(state.opt.w_g.m.iter().any(|v| *v != 0.0));
        assert_eq!(reloaded.opt, state.opt, "Adam moments drifted");

        // resume: one more identical (noise-free, so deterministic) step
        // on the original and the reloaded state must agree bit for bit
        let sched2 = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let m_orig = trainer
            .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
            .unwrap();
        let m_back = trainer
            .step_streamed(&sched2, &mut reloaded, &xs, &targets, 0.05, None)
            .unwrap();
        assert_eq!(
            m_orig.loss.to_bits(),
            m_back.loss.to_bits(),
            "reloaded state drifted: {} vs {}",
            m_orig.loss,
            m_back.loss
        );
        for (a, b) in state.weights.iter().zip(reloaded.weights.iter()) {
            assert_eq!(a.w_in, b.w_in, "post-resume weights drifted");
            assert_eq!(a.w_out, b.w_out, "post-resume weights drifted");
        }
    }

    #[test]
    fn pre_adam_checkpoint_resumes_with_fresh_clock_and_persists_it() {
        use crate::coordinator::scheduler::ExpertBackend;
        use crate::coordinator::{Scheduler, ShardLayout};
        use crate::train::Trainer;
        use crate::util::rng::Rng;

        let (d, h, n, k) = (4, 6, 3, 1);
        let cfg = ModelConfig::native_moe("ckpt-preadam", d, n, k, h, 1, 4);
        let trainer = Trainer::native(cfg.clone());
        let donor = trainer.init_streamed(4);

        // simulate the old (pre-Adam) format: same param flattening,
        // empty optimizer sections, saved mid-run at step 1000
        let mut flat = Vec::new();
        flat.extend_from_slice(&donor.router.w_g);
        flat.extend_from_slice(donor.router.w_noise.as_ref().unwrap());
        for w in &donor.weights {
            flat.extend_from_slice(&w.w_in);
            flat.extend_from_slice(&w.w_out);
        }
        let legacy = TrainState {
            params: TensorF::new(vec![flat.len()], flat),
            m: TensorF::zeros(vec![0]),
            v: TensorF::zeros(vec![0]),
            step: 1000,
        };
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("preadam.ckpt");
        save(&path, &cfg.name, &legacy).unwrap();

        // fresh moments must restart the Adam bias-correction clock at
        // 0 even though the trainer step is 1000
        let mut state = load_streamed(&path, &cfg.name, &cfg).unwrap();
        assert_eq!(state.step, 1000);
        assert!(
            state.opt.clocks().iter().all(|t| *t == 0),
            "pre-Adam resume must reset every Adam clock"
        );

        // train a little, save with the new format, reload: the clock
        // (now 2, not 1002) must round-trip via the trailer
        let sched = Scheduler::new(ShardLayout::new(1, n), ExpertBackend::Native);
        let mut rng = Rng::new(8);
        let xs = vec![TensorF::new(
            vec![4, d],
            (0..4 * d).map(|_| rng.normal_f32()).collect(),
        )];
        let targets = vec![TensorF::new(
            vec![4, d],
            (0..4 * d).map(|_| rng.normal_f32() * 0.5).collect(),
        )];
        for _ in 0..2 {
            trainer
                .step_streamed(&sched, &mut state, &xs, &targets, 0.01, None)
                .unwrap();
        }
        assert_eq!(state.opt.w_g.t, 2);
        // deterministic routing: the noise net never saw a gradient,
        // so its own clock stays cold
        assert_eq!(state.opt.w_noise.as_ref().unwrap().t, 0);
        assert_eq!(state.step, 1002);
        let path2 = dir.join("preadam2.ckpt");
        save_streamed(&path2, &cfg.name, &state).unwrap();
        let back = load_streamed(&path2, &cfg.name, &cfg).unwrap();
        assert_eq!(back.step, 1002);
        assert_eq!(
            back.opt.w_g.t, 2,
            "Adam clocks must persist independently of the trainer step"
        );
        assert_eq!(back.opt, state.opt);
    }

    #[test]
    fn streamed_checkpoint_rejects_wrong_dims() {
        use crate::train::Trainer;

        let (d, h, n, k) = (4, 6, 3, 1);
        let cfg = ModelConfig::native_moe("ckpt-dims", d, n, k, h, 1, 4);
        let trainer = Trainer::native(cfg.clone());
        let state = trainer.init_streamed(2);
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dims.ckpt");
        save_streamed(&path, &cfg.name, &state).unwrap();
        let wrong = ModelConfig::native_moe("ckpt-dims", d, n + 1, k, h, 1, 4);
        assert!(load_streamed(&path, &cfg.name, &wrong).is_err());
    }

    #[test]
    fn streamed_checkpoint_rejects_hierarchical_routers_without_partial_file() {
        use crate::coordinator::router::RouterBackend;
        use crate::train::optimizer::StreamedOptState;

        let router = Router {
            backend: RouterBackend::Native,
            n_experts: 4,
            k: 1,
            groups: 2,
            d_model: 2,
            w_g: vec![0.0; 2 * 2],
            w_noise: None,
            w_g_sec: Some(vec![0.0; 2 * 2 * 2]),
            w_n_sec: None,
        };
        let opt = StreamedOptState::zeros(&router, &[]);
        let state =
            StreamedTrainState { router, weights: Vec::new(), opt, step: 0 };
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hier.ckpt");
        let _ = std::fs::remove_file(&path);
        let err = save_streamed(&path, "hier", &state).unwrap_err().to_string();
        // the documented error, no panic...
        assert!(err.contains("flat routers only"), "{err}");
        // ...and no partial file: the reject happens before create()
        assert!(
            !path.exists(),
            "failed hierarchical save must not leave a partial checkpoint"
        );
    }

    #[test]
    fn garbage_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path, "x").is_err());
    }
}
