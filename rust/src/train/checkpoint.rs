//! Checkpoints: flat param/opt buffers with a small self-describing
//! header.  Format (little-endian):
//!
//! ```text
//!   magic  "MOECKPT1"            8 bytes
//!   step   u64
//!   name   u32 len + utf-8       config name (sanity-checked on load)
//!   3 sections, each: u64 len + len * f32   (params, m, v)
//! ```
//!
//! The artifact-free streamed trainer state ([`StreamedTrainState`]) is
//! stored in the same container via [`save_streamed`] /
//! [`load_streamed`]: router and expert weights are flattened into the
//! `params` section in a fixed order (`w_g | w_noise? | per expert
//! w_in, w_out`) with empty optimizer sections (the streamed path is
//! plain SGD).  Whether the router had a noise net is recovered from
//! the section length, so both shapes round-trip.  This is also how
//! the serving runtime ([`crate::serve`]) freezes gating from a
//! training run.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::scheduler::ExpertWeights;
use crate::coordinator::Router;
use crate::runtime::{ModelConfig, TensorF};
use crate::train::trainer::{StreamedTrainState, TrainState};

const MAGIC: &[u8; 8] = b"MOECKPT1";

pub fn save(path: &Path, cfg_name: &str, state: &TrainState) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(cfg_name.len() as u32).to_le_bytes())?;
    f.write_all(cfg_name.as_bytes())?;
    for t in [&state.params, &state.m, &state.v] {
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        for v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path, expect_cfg: &str) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a moe checkpoint");
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let name_len = u32::from_le_bytes(b4) as usize;
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("checkpoint name")?;
    if name != expect_cfg {
        bail!("checkpoint is for config '{name}', expected '{expect_cfg}'");
    }
    let read_section = |f: &mut dyn Read| -> Result<TensorF> {
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(TensorF::new(vec![len], data))
    };
    let params = read_section(&mut f)?;
    let m = read_section(&mut f)?;
    let v = read_section(&mut f)?;
    Ok(TrainState { params, m, v, step })
}

/// Save a [`StreamedTrainState`] (module docs: flattening order
/// `w_g | w_noise? | per expert w_in, w_out`).  Flat routers only: the
/// format carries no hierarchical secondary gates, and saving a
/// truncated router would serve a different model than was trained.
pub fn save_streamed(
    path: &Path,
    cfg_name: &str,
    state: &StreamedTrainState,
) -> Result<()> {
    if state.router.groups > 0
        || state.router.w_g_sec.is_some()
        || state.router.w_n_sec.is_some()
    {
        bail!(
            "streamed checkpoints support flat routers only (hierarchical \
             gating has secondary weights this format does not carry)"
        );
    }
    let mut flat = Vec::new();
    flat.extend_from_slice(&state.router.w_g);
    if let Some(wn) = &state.router.w_noise {
        flat.extend_from_slice(wn);
    }
    for w in &state.weights {
        flat.extend_from_slice(&w.w_in);
        flat.extend_from_slice(&w.w_out);
    }
    let ts = TrainState {
        params: TensorF::new(vec![flat.len()], flat),
        m: TensorF::zeros(vec![0]),
        v: TensorF::zeros(vec![0]),
        step: state.step,
    };
    save(path, cfg_name, &ts)
}

/// Load a [`StreamedTrainState`] saved by [`save_streamed`].  `cfg`
/// supplies the dimensions the flat buffer is sliced by; the router's
/// noise net is detected from the section length.
pub fn load_streamed(
    path: &Path,
    expect_cfg: &str,
    cfg: &ModelConfig,
) -> Result<StreamedTrainState> {
    let ts = load(path, expect_cfg)?;
    let (d, h, n, k) = (cfg.d_model, cfg.expert_hidden, cfg.n_experts, cfg.k);
    let gate = d * n;
    let expert = 2 * d * h;
    let with_noise = 2 * gate + n * expert;
    let without = gate + n * expert;
    let flat = &ts.params.data;
    let has_noise = if flat.len() == with_noise {
        // ambiguous only if gate == 0, which new() forbids (d, n >= 1)
        true
    } else if flat.len() == without {
        false
    } else {
        bail!(
            "{path:?}: streamed checkpoint holds {} f32s but config \
             '{}' needs {} (with noise net) or {} (without)",
            flat.len(),
            cfg.name,
            with_noise,
            without
        );
    };
    let mut at = 0usize;
    let mut take = |len: usize| {
        let s = flat[at..at + len].to_vec();
        at += len;
        s
    };
    let w_g = take(gate);
    let w_noise = if has_noise { Some(take(gate)) } else { None };
    let weights = (0..n)
        .map(|_| ExpertWeights {
            w_in: take(d * h),
            w_out: take(h * d),
            d_model: d,
            hidden: h,
        })
        .collect();
    Ok(StreamedTrainState {
        router: Router::flat_native(d, n, k, w_g, w_noise),
        weights,
        step: ts.step,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let state = TrainState {
            params: TensorF::new(vec![5], vec![1.0, -2.0, 3.5, 0.0, 9.0]),
            m: TensorF::new(vec![2], vec![0.1, 0.2]),
            v: TensorF::new(vec![3], vec![7.0, 8.0, 9.0]),
            step: 42,
        };
        save(&path, "cfg-x", &state).unwrap();
        let back = load(&path, "cfg-x").unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.data, state.params.data);
        assert_eq!(back.m.data, state.m.data);
        assert_eq!(back.v.data, state.v.data);
    }

    #[test]
    fn wrong_config_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let state = TrainState {
            params: TensorF::zeros(vec![1]),
            m: TensorF::zeros(vec![1]),
            v: TensorF::zeros(vec![1]),
            step: 0,
        };
        save(&path, "cfg-a", &state).unwrap();
        assert!(load(&path, "cfg-b").is_err());
    }

    #[test]
    fn streamed_roundtrip_resumes_bit_identically() {
        use crate::coordinator::scheduler::ExpertBackend;
        use crate::coordinator::{Scheduler, ShardLayout};
        use crate::train::Trainer;
        use crate::util::rng::Rng;

        let (d, h, n, k) = (6, 10, 4, 2);
        let cfg = ModelConfig::native_moe("ckpt-stream", d, n, k, h, 2, 8);
        let trainer = Trainer::native(cfg.clone());
        let mut state = trainer.init_streamed(9);
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(31);
        let rows = 12;
        let mk = |rng: &mut Rng| {
            (0..2)
                .map(|_| {
                    TensorF::new(
                        vec![rows, d],
                        (0..rows * d).map(|_| rng.normal_f32()).collect(),
                    )
                })
                .collect::<Vec<_>>()
        };
        let xs = mk(&mut rng);
        let targets = mk(&mut rng);
        for _ in 0..5 {
            trainer
                .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
                .unwrap();
        }

        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("streamed.ckpt");
        save_streamed(&path, &cfg.name, &state).unwrap();
        let mut reloaded = load_streamed(&path, &cfg.name, &cfg).unwrap();
        assert_eq!(reloaded.step, state.step);
        assert_eq!(reloaded.router.w_g, state.router.w_g);
        assert_eq!(reloaded.router.w_noise, state.router.w_noise);
        for (a, b) in state.weights.iter().zip(reloaded.weights.iter()) {
            assert_eq!(a.w_in, b.w_in);
            assert_eq!(a.w_out, b.w_out);
        }

        // resume: one more identical (noise-free, so deterministic) step
        // on the original and the reloaded state must agree bit for bit
        let sched2 = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let m_orig = trainer
            .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
            .unwrap();
        let m_back = trainer
            .step_streamed(&sched2, &mut reloaded, &xs, &targets, 0.05, None)
            .unwrap();
        assert_eq!(
            m_orig.loss.to_bits(),
            m_back.loss.to_bits(),
            "reloaded state drifted: {} vs {}",
            m_orig.loss,
            m_back.loss
        );
        for (a, b) in state.weights.iter().zip(reloaded.weights.iter()) {
            assert_eq!(a.w_in, b.w_in, "post-resume weights drifted");
            assert_eq!(a.w_out, b.w_out, "post-resume weights drifted");
        }
    }

    #[test]
    fn streamed_checkpoint_rejects_wrong_dims() {
        use crate::train::Trainer;

        let (d, h, n, k) = (4, 6, 3, 1);
        let cfg = ModelConfig::native_moe("ckpt-dims", d, n, k, h, 1, 4);
        let trainer = Trainer::native(cfg.clone());
        let state = trainer.init_streamed(2);
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dims.ckpt");
        save_streamed(&path, &cfg.name, &state).unwrap();
        let wrong = ModelConfig::native_moe("ckpt-dims", d, n + 1, k, h, 1, 4);
        assert!(load_streamed(&path, &cfg.name, &wrong).is_err());
    }

    #[test]
    fn streamed_checkpoint_rejects_hierarchical_routers() {
        use crate::coordinator::router::RouterBackend;

        let router = Router {
            backend: RouterBackend::Native,
            n_experts: 4,
            k: 1,
            groups: 2,
            d_model: 2,
            w_g: vec![0.0; 2 * 2],
            w_noise: None,
            w_g_sec: Some(vec![0.0; 2 * 2 * 2]),
            w_n_sec: None,
        };
        let state = StreamedTrainState { router, weights: Vec::new(), step: 0 };
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hier.ckpt");
        let err = save_streamed(&path, "hier", &state).unwrap_err().to_string();
        assert!(err.contains("flat routers only"), "{err}");
    }

    #[test]
    fn garbage_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path, "x").is_err());
    }
}
