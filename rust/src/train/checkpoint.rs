//! Checkpoints: flat param/opt buffers with a small self-describing
//! header.  Format (little-endian):
//!
//! ```text
//!   magic  "MOECKPT1"            8 bytes
//!   step   u64
//!   name   u32 len + utf-8       config name (sanity-checked on load)
//!   3 sections, each: u64 len + len * f32   (params, m, v)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::TensorF;
use crate::train::trainer::TrainState;

const MAGIC: &[u8; 8] = b"MOECKPT1";

pub fn save(path: &Path, cfg_name: &str, state: &TrainState) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(cfg_name.len() as u32).to_le_bytes())?;
    f.write_all(cfg_name.as_bytes())?;
    for t in [&state.params, &state.m, &state.v] {
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        for v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path, expect_cfg: &str) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a moe checkpoint");
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let name_len = u32::from_le_bytes(b4) as usize;
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("checkpoint name")?;
    if name != expect_cfg {
        bail!("checkpoint is for config '{name}', expected '{expect_cfg}'");
    }
    let read_section = |f: &mut dyn Read| -> Result<TensorF> {
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(TensorF::new(vec![len], data))
    };
    let params = read_section(&mut f)?;
    let m = read_section(&mut f)?;
    let v = read_section(&mut f)?;
    Ok(TrainState { params, m, v, step })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let state = TrainState {
            params: TensorF::new(vec![5], vec![1.0, -2.0, 3.5, 0.0, 9.0]),
            m: TensorF::new(vec![2], vec![0.1, 0.2]),
            v: TensorF::new(vec![3], vec![7.0, 8.0, 9.0]),
            step: 42,
        };
        save(&path, "cfg-x", &state).unwrap();
        let back = load(&path, "cfg-x").unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.data, state.params.data);
        assert_eq!(back.m.data, state.m.data);
        assert_eq!(back.v.data, state.v.data);
    }

    #[test]
    fn wrong_config_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let state = TrainState {
            params: TensorF::zeros(vec![1]),
            m: TensorF::zeros(vec![1]),
            v: TensorF::zeros(vec![1]),
            step: 0,
        };
        save(&path, "cfg-a", &state).unwrap();
        assert!(load(&path, "cfg-b").is_err());
    }

    #[test]
    fn garbage_rejected() {
        let dir = std::env::temp_dir().join("moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path, "x").is_err());
    }
}
