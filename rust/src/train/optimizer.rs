//! Shared native optimizer: Adam (Kingma & Ba, algorithm 1) with
//! per-tensor first/second-moment state — the same optimizer the
//! artifact path bakes into its step graph, now available to the
//! artifact-free streamed trainer.  [`StreamedOptState`] mirrors the
//! streamed model tensors (`w_g | w_noise? | per expert w_in, w_out`,
//! plus hierarchical secondaries when present) and flattens in exactly
//! that order so `checkpoint::save_streamed` / `load_streamed` can
//! thread it through the `m` / `v` sections of the existing container.

use crate::coordinator::router::Router;
use crate::coordinator::scheduler::ExpertWeights;
use crate::gating::backward::GateGrads;

/// Adam hyperparameters (the paper-standard defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// First/second moments of one parameter tensor, plus that tensor's
/// own bias-correction clock.  The clock is per tensor — not shared
/// with the trainer step — so a tensor whose updates begin mid-run
/// (gating un-frozen after baseline steps, a noise net that only gets
/// gradients on noisy steps, fresh moments after a pre-Adam-checkpoint
/// resume) still gets the correct cold-start bias correction instead
/// of a ~3× first-step overshoot.
#[derive(Clone, Debug, PartialEq)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// updates applied to this tensor so far
    pub t: u64,
}

impl AdamState {
    pub fn zeros(len: usize) -> Self {
        AdamState { m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    /// One Adam update: advances this tensor's clock, then
    /// `m ← β₁m + (1−β₁)g`, `v ← β₂v + (1−β₂)g²`,
    /// `w ← w − lr · m̂ / (√v̂ + ε)` with bias correction at the new
    /// (1-based) clock value.
    pub fn update(&mut self, p: &AdamParams, lr: f32, w: &mut [f32], g: &[f32]) {
        assert_eq!(w.len(), g.len(), "adam: grad shape");
        assert_eq!(w.len(), self.m.len(), "adam: moment shape");
        self.t += 1;
        let t = self.t.clamp(1, i32::MAX as u64) as i32;
        let bc1 = 1.0 - p.beta1.powi(t);
        let bc2 = 1.0 - p.beta2.powi(t);
        for i in 0..w.len() {
            self.m[i] = p.beta1 * self.m[i] + (1.0 - p.beta1) * g[i];
            self.v[i] = p.beta2 * self.v[i] + (1.0 - p.beta2) * g[i] * g[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            w[i] -= lr * mhat / (vhat.sqrt() + p.eps);
        }
    }
}

/// Optimizer state for every tensor of a
/// [`StreamedTrainState`](crate::train::StreamedTrainState), in the
/// checkpoint flattening order.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamedOptState {
    pub w_g: AdamState,
    pub w_noise: Option<AdamState>,
    /// per expert: (w_in, w_out) moments
    pub experts: Vec<(AdamState, AdamState)>,
    pub w_g_sec: Option<AdamState>,
    pub w_n_sec: Option<AdamState>,
}

impl StreamedOptState {
    /// Fresh (all-zero) moments shaped like the given model tensors.
    pub fn zeros(router: &Router, weights: &[ExpertWeights]) -> Self {
        StreamedOptState {
            w_g: AdamState::zeros(router.w_g.len()),
            w_noise: router
                .w_noise
                .as_ref()
                .map(|w| AdamState::zeros(w.len())),
            experts: weights
                .iter()
                .map(|w| {
                    (
                        AdamState::zeros(w.w_in.len()),
                        AdamState::zeros(w.w_out.len()),
                    )
                })
                .collect(),
            w_g_sec: router
                .w_g_sec
                .as_ref()
                .map(|w| AdamState::zeros(w.len())),
            w_n_sec: router
                .w_n_sec
                .as_ref()
                .map(|w| AdamState::zeros(w.len())),
        }
    }

    /// Flatten (m, v) in the checkpoint parameter order
    /// `w_g | w_noise? | per expert w_in, w_out` (flat routers only —
    /// the container carries no secondary gates, and `save_streamed`
    /// rejects hierarchical states before calling this).
    pub fn flatten(&self) -> (Vec<f32>, Vec<f32>) {
        let mut m = Vec::new();
        let mut v = Vec::new();
        let push = |s: &AdamState, m: &mut Vec<f32>, v: &mut Vec<f32>| {
            m.extend_from_slice(&s.m);
            v.extend_from_slice(&s.v);
        };
        push(&self.w_g, &mut m, &mut v);
        if let Some(s) = &self.w_noise {
            push(s, &mut m, &mut v);
        }
        for (w_in, w_out) in &self.experts {
            push(w_in, &mut m, &mut v);
            push(w_out, &mut m, &mut v);
        }
        (m, v)
    }

    /// Rebuild from checkpoint `m` / `v` sections (inverse of
    /// [`flatten`](Self::flatten)).  Empty sections mean a checkpoint
    /// from before moments were carried — resume with fresh state and
    /// every tensor's bias-correction clock restarted at 0 (ignoring
    /// `fallback_t`).  Non-empty sections must cover the model exactly;
    /// every tensor's clock is seeded with `fallback_t` — the loader
    /// then overwrites the clocks from the checkpoint's `ADAMCLK1`
    /// trailer via [`set_clocks`](Self::set_clocks) when present
    /// (falling back to the trainer step, which coincides with the
    /// clocks for runs trained from step 0 under Adam with noise on).
    pub fn from_flat(
        m: &[f32],
        v: &[f32],
        d: usize,
        h: usize,
        n: usize,
        has_noise: bool,
        fallback_t: u64,
    ) -> anyhow::Result<Self> {
        let gate = d * n;
        let want = gate * if has_noise { 2 } else { 1 } + n * 2 * d * h;
        if m.is_empty() && v.is_empty() {
            return Ok(StreamedOptState {
                w_g: AdamState::zeros(gate),
                w_noise: has_noise.then(|| AdamState::zeros(gate)),
                experts: (0..n)
                    .map(|_| (AdamState::zeros(d * h), AdamState::zeros(h * d)))
                    .collect(),
                w_g_sec: None,
                w_n_sec: None,
            });
        }
        if m.len() != want || v.len() != want {
            anyhow::bail!(
                "optimizer sections hold {}/{} f32s but the model needs {want}",
                m.len(),
                v.len()
            );
        }
        let mut at = 0usize;
        let mut take = |len: usize| {
            let s = AdamState {
                m: m[at..at + len].to_vec(),
                v: v[at..at + len].to_vec(),
                t: fallback_t,
            };
            at += len;
            s
        };
        let w_g = take(gate);
        let w_noise = if has_noise { Some(take(gate)) } else { None };
        let experts = (0..n).map(|_| (take(d * h), take(h * d))).collect();
        Ok(StreamedOptState {
            w_g,
            w_noise,
            experts,
            w_g_sec: None,
            w_n_sec: None,
        })
    }

    /// Per-tensor bias-correction clocks in the flatten order
    /// `w_g | w_noise? | per expert w_in, w_out` (what the checkpoint
    /// trailer persists).
    pub fn clocks(&self) -> Vec<u64> {
        let mut out = vec![self.w_g.t];
        if let Some(s) = &self.w_noise {
            out.push(s.t);
        }
        for (w_in, w_out) in &self.experts {
            out.push(w_in.t);
            out.push(w_out.t);
        }
        out
    }

    /// Restore per-tensor clocks saved by [`clocks`](Self::clocks);
    /// the count must match this state's tensor count exactly.
    pub fn set_clocks(&mut self, clocks: &[u64]) -> anyhow::Result<()> {
        let want = 1
            + usize::from(self.w_noise.is_some())
            + 2 * self.experts.len();
        if clocks.len() != want {
            anyhow::bail!(
                "checkpoint carries {} optimizer clocks but the model has \
                 {want} tensors",
                clocks.len()
            );
        }
        let mut it = clocks.iter().copied();
        self.w_g.t = it.next().unwrap();
        if let Some(s) = self.w_noise.as_mut() {
            s.t = it.next().unwrap();
        }
        for (w_in, w_out) in self.experts.iter_mut() {
            w_in.t = it.next().unwrap();
            w_out.t = it.next().unwrap();
        }
        Ok(())
    }

    /// One Adam update of every gating tensor that received a gradient
    /// this step (`w_g` always; the optional tensors when present).
    /// A gradient with no matching weight or moments is an error, not a
    /// silent skip — a state assembled with mismatched router/opt
    /// shapes must fail loudly instead of letting a tensor quietly stop
    /// learning.  Each tensor advances its own bias-correction clock.
    pub fn update_gating(
        &mut self,
        p: &AdamParams,
        lr: f32,
        router: &mut Router,
        g: &GateGrads,
    ) -> anyhow::Result<()> {
        self.w_g.update(p, lr, &mut router.w_g, &g.w_g);
        let slots = [
            (
                "w_noise",
                router.w_noise.as_mut(),
                g.w_noise.as_ref(),
                self.w_noise.as_mut(),
            ),
            (
                "w_g_sec",
                router.w_g_sec.as_mut(),
                g.w_g_sec.as_ref(),
                self.w_g_sec.as_mut(),
            ),
            (
                "w_n_sec",
                router.w_n_sec.as_mut(),
                g.w_n_sec.as_ref(),
                self.w_n_sec.as_mut(),
            ),
        ];
        for (name, w, grad, st) in slots {
            match (w, grad, st) {
                (Some(w), Some(grad), Some(st)) => {
                    st.update(p, lr, w, grad);
                }
                // no gradient this step (e.g. noise net under
                // deterministic routing) — nothing to apply
                (_, None, _) => {}
                (w, Some(_), st) => anyhow::bail!(
                    "gating tensor {name} has a gradient but weight \
                     present={} / moments present={} — optimizer state \
                     does not match the router",
                    w.is_some(),
                    st.is_some()
                ),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_adam_step_is_signed_lr() {
        // with zero moments, step 1 moves each weight by ~lr·sign(g)
        // (bias correction cancels the (1−β) factors exactly)
        let p = AdamParams::default();
        let mut st = AdamState::zeros(3);
        let mut w = vec![1.0f32, -2.0, 0.5];
        let g = vec![0.3f32, -4.0, 0.0];
        st.update(&p, 0.01, &mut w, &g);
        assert_eq!(st.t, 1, "update advances the tensor's own clock");
        assert!((w[0] - (1.0 - 0.01)).abs() < 1e-4, "w0={}", w[0]);
        assert!((w[1] - (-2.0 + 0.01)).abs() < 1e-4, "w1={}", w[1]);
        assert_eq!(w[2], 0.5, "zero grad, zero moments: no movement");
    }

    #[test]
    fn adam_matches_reference_recurrence() {
        // two hand-unrolled updates against the algorithm-1 recurrence
        let p = AdamParams { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let mut st = AdamState::zeros(1);
        let mut w = vec![0.0f32];
        let (mut m, mut v) = (0.0f64, 0.0f64);
        let mut w_ref = 0.0f64;
        for (t, g) in [0.5f64, -0.25].iter().enumerate() {
            st.update(&p, 0.1, &mut w, &[*g as f32]);
            m = 0.9 * m + 0.1 * g;
            v = 0.999 * v + 0.001 * g * g;
            let mhat = m / (1.0 - 0.9f64.powi(t as i32 + 1));
            let vhat = v / (1.0 - 0.999f64.powi(t as i32 + 1));
            w_ref -= 0.1 * mhat / (vhat.sqrt() + 1e-8);
            assert!(
                (w[0] as f64 - w_ref).abs() < 1e-5,
                "t={t}: {} vs {w_ref}",
                w[0]
            );
        }
        assert_eq!(st.m.len(), 1);
        assert!(st.v[0] > 0.0);
    }

    #[test]
    fn opt_state_flatten_roundtrips() {
        let (d, h, n) = (3, 4, 2);
        let mut st = StreamedOptState {
            w_g: AdamState::zeros(d * n),
            w_noise: Some(AdamState::zeros(d * n)),
            experts: (0..n)
                .map(|_| (AdamState::zeros(d * h), AdamState::zeros(h * d)))
                .collect(),
            w_g_sec: None,
            w_n_sec: None,
        };
        // stamp recognizable values
        let mut c = 0.0f32;
        for s in [&mut st.w_g]
            .into_iter()
            .chain(st.w_noise.as_mut())
        {
            for x in s.m.iter_mut().chain(s.v.iter_mut()) {
                c += 1.0;
                *x = c;
            }
        }
        for (a, b) in st.experts.iter_mut() {
            for x in a
                .m
                .iter_mut()
                .chain(a.v.iter_mut())
                .chain(b.m.iter_mut())
                .chain(b.v.iter_mut())
            {
                c += 1.0;
                *x = c;
            }
        }
        // clocks are not part of the m/v sections: from_flat seeds them
        // with the fallback, so stamp the same value here for equality
        st.w_g.t = 7;
        st.w_noise.as_mut().unwrap().t = 7;
        for (a, b) in st.experts.iter_mut() {
            a.t = 7;
            b.t = 7;
        }
        let (m, v) = st.flatten();
        let want = d * n * 2 + n * 2 * d * h;
        assert_eq!(m.len(), want);
        assert_eq!(v.len(), want);
        let back =
            StreamedOptState::from_flat(&m, &v, d, h, n, true, 7).unwrap();
        assert_eq!(back, st);
        // per-tensor clocks round-trip through clocks()/set_clocks()
        let mut with_clocks = back.clone();
        with_clocks.set_clocks(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(with_clocks.clocks(), vec![1, 2, 3, 4, 5, 6]);
        assert!(with_clocks.set_clocks(&[1, 2]).is_err(), "count mismatch");
        // empty sections resume fresh, every clock reset to 0 no matter
        // how far the run had trained
        let fresh =
            StreamedOptState::from_flat(&[], &[], d, h, n, true, 1000).unwrap();
        assert!(fresh.w_g.m.iter().all(|x| *x == 0.0));
        assert_eq!(fresh.experts.len(), n);
        assert!(
            fresh.clocks().iter().all(|t| *t == 0),
            "fresh moments must restart the Adam clocks"
        );
        // wrong length is a clean error
        assert!(
            StreamedOptState::from_flat(&m[1..], &v[1..], d, h, n, true, 7)
                .is_err()
        );
    }

    #[test]
    fn update_gating_rejects_mismatched_state() {
        use crate::coordinator::router::Router;

        let (d, n) = (2, 3);
        let mut router = Router::flat_native(
            d,
            n,
            1,
            vec![0.0; d * n],
            Some(vec![0.0; d * n]),
        );
        // opt state built WITHOUT a noise slot: a w_noise gradient must
        // be a loud error, not a silent skip
        let mut opt = StreamedOptState {
            w_g: AdamState::zeros(d * n),
            w_noise: None,
            experts: Vec::new(),
            w_g_sec: None,
            w_n_sec: None,
        };
        let g = GateGrads {
            w_g: vec![0.1; d * n],
            w_noise: Some(vec![0.1; d * n]),
            w_g_sec: None,
            w_n_sec: None,
        };
        let err = opt
            .update_gating(&AdamParams::default(), 0.01, &mut router, &g)
            .unwrap_err()
            .to_string();
        assert!(err.contains("w_noise"), "{err}");
        // with the matching slot present the same update applies cleanly
        let mut opt2 = StreamedOptState::zeros(&router, &[]);
        opt2.update_gating(&AdamParams::default(), 0.01, &mut router, &g)
            .unwrap();
        assert_eq!(opt2.w_g.t, 1);
        assert_eq!(opt2.w_noise.as_ref().unwrap().t, 1);
        assert!(router.w_g.iter().all(|w| *w != 0.0));
        assert!(router.w_noise.as_ref().unwrap().iter().all(|w| *w != 0.0));
    }
}
