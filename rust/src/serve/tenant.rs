//! Multi-tenant admission front-end: per-tenant bounded queues with a
//! weighted-fair drain, capability-first backend routing, and
//! per-tenant SLO ledgers.
//!
//! One global FIFO cannot serve many users: a single tenant bursting
//! at 10× capacity owns the whole queue, and every other tenant's
//! requests are shed or starved behind its backlog.  This module puts
//! an isolation boundary at admission:
//!
//! - [`TenantQueue`] — one bounded FIFO lane per tenant, drained into
//!   the [`MicroBatcher`](crate::serve::MicroBatcher) by a
//!   [`DrainPolicy`]: **weighted-fair** (deficit round-robin: each
//!   lane accrues token credit proportional to its weight and spends
//!   it as its requests are popped, so a backlogged tenant gets a
//!   long-run token share of `w_t / Σw_active` no matter how hard
//!   another tenant floods) or **global FIFO** (one shared depth bound,
//!   strict arrival order — the contrast baseline that demonstrably
//!   violates isolation under a heavy hitter).  Admission, shedding,
//!   `peak_depth` and the conservation ledger are all per-lane, and
//!   lane ledgers sum to the queue's global ledger.
//! - Capability-first admission ([`TenantServeLoop`]) — per the nexus
//!   router ordering, *hard filters* run before any load scoring: a
//!   backend that can't hold the request's rows, serve the tenant's
//!   required [`Precision`] / model variant, or meet its deadline at
//!   the current EWMA throughput estimate and `live_fraction` is
//!   disqualified outright.  Only the surviving candidates are scored
//!   (least estimated wait), so load balancing never routes a request
//!   somewhere it would be served wrong — a missing capability is a
//!   shed, not a soft penalty.
//! - Per-tenant [`ServeStats`] — every tenant gets its own latency
//!   histograms and request ledger (`offered == completed + shed +
//!   failed`), published under `serve_*{tenant="..."}` registry keys
//!   ([`ServeStats::publish_with`]); tenant ledgers sum exactly to the
//!   global ledger (asserted in `rust/tests/tenants.rs`).
//!
//! The serve clock is the same hybrid as
//! [`ServeLoop`](crate::serve::ServeLoop): deterministic seeded
//! arrival stamps, measured engine walls, open-loop admission.
//! Backends execute one at a time on the harness clock (the fleet is
//! modelled sequentially), which keeps queueing dynamics reproducible
//! and per-request outputs bit-identical to running each request alone
//! on its assigned backend.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::kernels::quant::Precision;
use crate::runtime::TensorF;
use crate::serve::backend::ServeBackend;
use crate::serve::batcher::{BatchSource, MicroBatcher};
use crate::serve::queue::{AdmissionPolicy, ServeRequest};
use crate::serve::stats::ServeStats;

/// One tenant's contract with the front-end: identity, fair-share
/// weight, lane capacity, latency SLO and capability requirements.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// fair-share weight (≥ 1): a backlogged tenant's long-run token
    /// share under [`DrainPolicy::WeightedFair`] is `w / Σw_active`
    pub weight: u64,
    /// lane depth bound (requests); under [`DrainPolicy::GlobalFifo`]
    /// the *sum* of lane bounds is one shared bound instead
    pub queue_depth: usize,
    /// per-request latency SLO; when set, arrivals that cannot meet it
    /// are shed up-front and completions past it count as violations
    pub deadline_ns: Option<u64>,
    /// hard capability requirement: only backends serving at exactly
    /// this precision may take this tenant's requests
    pub required_precision: Option<Precision>,
    /// hard capability requirement: only backends serving this model
    /// variant may take this tenant's requests
    pub required_variant: Option<String>,
}

impl TenantSpec {
    /// A plain tenant: weight 1, no SLO, no capability pins.
    pub fn new(name: &str, queue_depth: usize) -> Self {
        TenantSpec {
            name: name.to_string(),
            weight: 1,
            queue_depth,
            deadline_ns: None,
            required_precision: None,
            required_variant: None,
        }
    }
}

/// How the multi-tenant queue drains into the micro-batcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainPolicy {
    /// strict arrival order over one shared depth bound — no isolation:
    /// a heavy hitter owns the queue and starves everyone else (kept as
    /// the measurable baseline the fairness tests contrast against)
    GlobalFifo,
    /// deficit round-robin over per-lane bounds: token service
    /// proportional to tenant weight, lane-local shedding
    WeightedFair,
}

/// Per-tenant lane: a FIFO plus its own cached token count and
/// admission ledger (same O(1) `depth_tokens` invariant as
/// [`RequestQueue`](crate::serve::RequestQueue)).
struct Lane {
    queue: std::collections::VecDeque<ServeRequest>,
    /// running sum of queued rows, updated on every push/pop/shed
    tokens: usize,
    offered: u64,
    shed: u64,
    popped: u64,
    peak_depth: usize,
    /// DRR token credit (unused under [`DrainPolicy::GlobalFifo`])
    deficit: u64,
}

impl Lane {
    fn new() -> Self {
        Lane {
            queue: std::collections::VecDeque::new(),
            tokens: 0,
            offered: 0,
            shed: 0,
            popped: 0,
            peak_depth: 0,
            deficit: 0,
        }
    }

    fn push(&mut self, req: ServeRequest) {
        self.tokens += req.rows();
        self.queue.push_back(req);
        self.peak_depth = self.peak_depth.max(self.queue.len());
    }

    fn pop(&mut self) -> Option<ServeRequest> {
        let req = self.queue.pop_front();
        if let Some(r) = &req {
            self.tokens -= r.rows();
        }
        req
    }
}

/// One queue-level ledger row (`offered == popped + shed + queued`,
/// per lane and summed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneLedger {
    pub offered: u64,
    pub popped: u64,
    pub shed: u64,
    pub queued: u64,
}

/// Per-tenant bounded FIFOs drained by a [`DrainPolicy`].  Implements
/// [`BatchSource`], so the existing [`MicroBatcher`] forms batches
/// from it unchanged — `pop_next` follows DRR or global-FIFO order
/// instead of a single lane's FIFO.
pub struct TenantQueue {
    policy: DrainPolicy,
    admission: AdmissionPolicy,
    lanes: Vec<Lane>,
    weights: Vec<u64>,
    depths: Vec<usize>,
    /// shared bound under [`DrainPolicy::GlobalFifo`] (Σ lane depths)
    total_depth: usize,
    /// DRR replenish unit per weight point (tokens)
    quantum: u64,
    /// round-robin cursor for DRR lane scans
    next_rr: usize,
    /// lane selected by the last [`BatchSource::next_rows`] call,
    /// consumed by `pop_next`; invalidated by any offer/shed
    pending: Option<usize>,
    /// high-water total depth across all lanes (bounded-memory witness)
    peak_total: usize,
}

impl TenantQueue {
    pub fn new(
        specs: &[TenantSpec],
        admission: AdmissionPolicy,
        policy: DrainPolicy,
    ) -> Result<Self> {
        if specs.is_empty() {
            bail!("tenant queue needs at least one tenant");
        }
        for (i, s) in specs.iter().enumerate() {
            if s.weight == 0 {
                bail!("tenant {} ({}) has zero weight", i, s.name);
            }
            if s.queue_depth == 0 {
                bail!("tenant {} ({}) has zero queue depth", i, s.name);
            }
            if specs[..i].iter().any(|o| o.name == s.name) {
                bail!("duplicate tenant name {}", s.name);
            }
        }
        Ok(TenantQueue {
            policy,
            admission,
            lanes: specs.iter().map(|_| Lane::new()).collect(),
            weights: specs.iter().map(|s| s.weight).collect(),
            depths: specs.iter().map(|s| s.queue_depth).collect(),
            total_depth: specs.iter().map(|s| s.queue_depth).sum(),
            quantum: 1,
            next_rr: 0,
            pending: None,
            peak_total: 0,
        })
    }

    pub fn n_tenants(&self) -> usize {
        self.lanes.len()
    }

    pub fn policy(&self) -> DrainPolicy {
        self.policy
    }

    pub fn total_len(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.len()).sum()
    }

    pub fn lane_len(&self, t: usize) -> usize {
        self.lanes[t].queue.len()
    }

    pub fn lane_tokens(&self, t: usize) -> usize {
        self.lanes[t].tokens
    }

    /// High-water depth of one tenant's lane.
    pub fn peak_depth(&self, t: usize) -> usize {
        self.lanes[t].peak_depth
    }

    /// High-water total depth across all lanes.
    pub fn peak_total(&self) -> usize {
        self.peak_total
    }

    /// Queue-level conservation row for one lane:
    /// `offered == popped + shed + queued` (asserted per lane and as a
    /// sum in the tenant tests).
    pub fn ledger(&self, t: usize) -> LaneLedger {
        let l = &self.lanes[t];
        LaneLedger {
            offered: l.offered,
            popped: l.popped,
            shed: l.shed,
            queued: l.queue.len() as u64,
        }
    }

    /// Would an [`offer`](Self::offer) for tenant `t` be refused
    /// outright?  True only under [`AdmissionPolicy::Reject`] at a full
    /// lane (weighted-fair) or full shared queue (global FIFO) — lets
    /// the driver skip materialising a doomed request, like
    /// [`RequestQueue::will_reject_next`](crate::serve::RequestQueue::will_reject_next).
    pub fn will_reject(&self, t: usize) -> bool {
        if !matches!(self.admission, AdmissionPolicy::Reject) {
            return false;
        }
        match self.policy {
            DrainPolicy::GlobalFifo => self.total_len() >= self.total_depth,
            DrainPolicy::WeightedFair => {
                self.lanes[t].queue.len() >= self.depths[t]
            }
        }
    }

    /// Record the refusal of a request for tenant `t` that the caller
    /// never materialised (admission-full rejection or up-front
    /// infeasibility): one offer, one shed, lanes untouched.
    pub fn reject(&mut self, t: usize) {
        self.lanes[t].offered += 1;
        self.lanes[t].shed += 1;
    }

    /// Effective token backlog a new `rows`-token request from tenant
    /// `t` waits behind.  Global FIFO: the whole shared queue.
    /// Weighted-fair: the tenant's own lane, stretched by the inverse
    /// of its service share (`Σw_active / w_t`) since DRR interleaves
    /// other backlogged lanes into its drain.
    pub fn wait_tokens(&self, t: usize, rows: usize) -> usize {
        match self.policy {
            DrainPolicy::GlobalFifo => self.depth_tokens() + rows,
            DrainPolicy::WeightedFair => {
                let w_active: u64 = self
                    .lanes
                    .iter()
                    .zip(&self.weights)
                    .enumerate()
                    .filter(|(i, (l, _))| *i == t || !l.queue.is_empty())
                    .map(|(_, (_, w))| *w)
                    .sum();
                let share = w_active as f64 / self.weights[t] as f64;
                ((self.lanes[t].tokens + rows) as f64 * share).ceil() as usize
            }
        }
    }

    /// Deadline feasibility for tenant `t`, same throughput model as
    /// [`RequestQueue::feasible`](crate::serve::RequestQueue::feasible)
    /// but over the policy-aware effective backlog
    /// ([`wait_tokens`](Self::wait_tokens)).
    pub fn feasible(
        &self,
        t: usize,
        rows: usize,
        est_ns_per_token: f64,
        live_fraction: f64,
        deadline_ns: u64,
    ) -> bool {
        if est_ns_per_token <= 0.0 {
            return true;
        }
        let eff = est_ns_per_token / live_fraction.clamp(1e-9, 1.0);
        self.wait_tokens(t, rows) as f64 * eff <= deadline_ns as f64
    }

    /// Offer a request for tenant `t`.  Returns the `(tenant, request)`
    /// pairs admission control dropped: the newcomer under
    /// [`AdmissionPolicy::Reject`], displaced oldest requests under
    /// [`AdmissionPolicy::ShedOldest`] — which under
    /// [`DrainPolicy::GlobalFifo`] may belong to *other* tenants (the
    /// cross-tenant interference the fairness tests measure), but under
    /// [`DrainPolicy::WeightedFair`] only ever come from `t`'s own lane.
    pub fn offer(
        &mut self,
        t: usize,
        req: ServeRequest,
    ) -> Vec<(usize, ServeRequest)> {
        self.pending = None;
        self.lanes[t].offered += 1;
        let mut dropped = Vec::new();
        let full = match self.policy {
            DrainPolicy::GlobalFifo => self.total_len() >= self.total_depth,
            DrainPolicy::WeightedFair => {
                self.lanes[t].queue.len() >= self.depths[t]
            }
        };
        if full {
            match self.admission {
                AdmissionPolicy::Reject => {
                    self.lanes[t].shed += 1;
                    dropped.push((t, req));
                    return dropped;
                }
                AdmissionPolicy::ShedOldest => match self.policy {
                    DrainPolicy::GlobalFifo => {
                        while self.total_len() >= self.total_depth {
                            // globally oldest = smallest request id
                            // (ids are assigned in arrival order)
                            let victim = match self.fifo_lane() {
                                Some(v) => v,
                                None => break,
                            };
                            if let Some(old) = self.lanes[victim].pop() {
                                self.lanes[victim].shed += 1;
                                dropped.push((victim, old));
                            }
                        }
                    }
                    DrainPolicy::WeightedFair => {
                        while self.lanes[t].queue.len() >= self.depths[t] {
                            match self.lanes[t].pop() {
                                Some(old) => {
                                    self.lanes[t].shed += 1;
                                    dropped.push((t, old));
                                }
                                None => break,
                            }
                        }
                    }
                },
            }
        }
        self.lanes[t].push(req);
        self.peak_total = self.peak_total.max(self.total_len());
        dropped
    }

    /// Lane holding the globally oldest queued request (smallest id).
    fn fifo_lane(&self) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.queue.front().map(|r| (i, r.id)))
            .min_by_key(|&(_, id)| id)
            .map(|(i, _)| i)
    }

    /// DRR lane selection: scan round-robin for a lane whose deficit
    /// covers its head request; if none, replenish every backlogged
    /// lane by `quantum × weight` and rescan.  Terminates because some
    /// lane is non-empty and deficits grow by ≥ `quantum` per round.
    fn drr_lane(&mut self) -> Option<usize> {
        if self.is_empty() {
            return None;
        }
        let n = self.lanes.len();
        loop {
            for i in 0..n {
                let lane = (self.next_rr + i) % n;
                if let Some(head) = self.lanes[lane].queue.front() {
                    if self.lanes[lane].deficit >= head.rows() as u64 {
                        return Some(lane);
                    }
                }
            }
            for (l, w) in self.lanes.iter_mut().zip(&self.weights) {
                if !l.queue.is_empty() {
                    l.deficit += self.quantum * w;
                }
            }
        }
    }

    fn select_lane(&mut self) -> Option<usize> {
        match self.policy {
            DrainPolicy::GlobalFifo => self.fifo_lane(),
            DrainPolicy::WeightedFair => self.drr_lane(),
        }
    }
}

impl BatchSource for TenantQueue {
    fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.queue.is_empty())
    }

    fn depth_tokens(&self) -> usize {
        self.lanes.iter().map(|l| l.tokens).sum()
    }

    fn oldest_arrival_ns(&self) -> Option<u64> {
        self.lanes
            .iter()
            .filter_map(|l| l.queue.front().map(|r| r.arrival_ns))
            .min()
    }

    fn next_rows(&mut self) -> Option<usize> {
        if self.pending.is_none() {
            self.pending = self.select_lane();
        }
        self.pending
            .and_then(|t| self.lanes[t].queue.front().map(|r| r.rows()))
    }

    fn pop_next(&mut self) -> Option<ServeRequest> {
        let t = match self.pending.take().or_else(|| self.select_lane()) {
            Some(t) => t,
            None => return None,
        };
        let req = self.lanes[t].pop()?;
        self.lanes[t].popped += 1;
        if matches!(self.policy, DrainPolicy::WeightedFair) {
            // spend the credit; an emptied lane forfeits leftovers
            // (classic DRR — credit never accrues while idle)
            let l = &mut self.lanes[t];
            l.deficit = l.deficit.saturating_sub(req.rows() as u64);
            if l.queue.is_empty() {
                l.deficit = 0;
            }
            self.next_rr = (t + 1) % self.lanes.len();
        }
        Some(req)
    }
}

/// One multi-tenant trace entry: which tenant, when, and the ragged
/// `(rows, d)` activations.
pub struct TenantRequest {
    pub tenant: usize,
    pub arrival_ns: u64,
    pub x: TensorF,
}

/// Front-end knobs (per-tenant contracts live in [`TenantSpec`]s).
#[derive(Clone, Debug)]
pub struct TenantServeConfig {
    pub admission: AdmissionPolicy,
    pub drain: DrainPolicy,
    /// dispatch a partial batch once the oldest request waited this long
    pub latency_budget_ns: u64,
    /// keep per-request outputs (and backend assignments) in the report
    pub capture_outputs: bool,
}

impl Default for TenantServeConfig {
    fn default() -> Self {
        TenantServeConfig {
            admission: AdmissionPolicy::Reject,
            drain: DrainPolicy::WeightedFair,
            latency_budget_ns: 1_000_000, // 1ms
            capture_outputs: false,
        }
    }
}

/// Result of one multi-tenant trace replay: the global ledger, one
/// [`ServeStats`] per tenant (request-level fields sum exactly to the
/// global ones), and per-request outputs / backend assignments when
/// captured.
pub struct TenantServeReport {
    pub global: ServeStats,
    pub per_tenant: Vec<ServeStats>,
    /// tenant names, index-aligned with `per_tenant`
    pub tenants: Vec<String>,
    /// per-trace-index outputs when `capture_outputs` was set (`None`
    /// for shed requests); empty otherwise
    pub outputs: Vec<Option<TensorF>>,
    /// per-trace-index backend that served the request (`None` for
    /// shed); empty unless `capture_outputs` was set
    pub assigned_backend: Vec<Option<usize>>,
}

impl TenantServeReport {
    /// Publish the global ledger under the plain `serve_*` keys and
    /// every tenant's ledger under `serve_*{tenant="..."}`.
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        self.global.publish(reg);
        for (name, stats) in self.tenants.iter().zip(&self.per_tenant) {
            stats.publish_with(reg, &[("tenant", name)]);
        }
    }

    /// One summary line per tenant (name-prefixed), plus a global line.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut lines: Vec<String> = self
            .tenants
            .iter()
            .zip(&self.per_tenant)
            .map(|(name, s)| format!("{name:>10}  {}", s.summary_line()))
            .collect();
        lines.push(format!("{:>10}  {}", "all", self.global.summary_line()));
        lines
    }
}

/// The multi-tenant serve driver: routes each arrival to a capable
/// backend (hard filters first, then least-estimated-wait scoring),
/// queues it in that backend's [`TenantQueue`], and drives
/// micro-batched forward steps per backend on one shared serve clock.
pub struct TenantServeLoop {
    backends: Vec<Box<dyn ServeBackend>>,
    specs: Vec<TenantSpec>,
    cfg: TenantServeConfig,
}

impl TenantServeLoop {
    /// All backends must share one model width (`d_model`) — they may
    /// differ in checkpoint, precision and variant, which is exactly
    /// what capability routing selects over.
    pub fn new(
        backends: Vec<Box<dyn ServeBackend>>,
        specs: Vec<TenantSpec>,
        cfg: TenantServeConfig,
    ) -> Result<Self> {
        if backends.is_empty() {
            bail!("tenant serve loop needs at least one backend");
        }
        if specs.is_empty() {
            bail!("tenant serve loop needs at least one tenant");
        }
        let d = backends[0].caps().d_model;
        for b in &backends {
            if b.caps().d_model != d {
                bail!(
                    "backend {} has d_model {} (fleet {})",
                    b.name(),
                    b.caps().d_model,
                    d
                );
            }
        }
        // fail at construction when a tenant's capability pins match no
        // backend at all — every one of its requests would be shed
        for s in &specs {
            let any = backends.iter().any(|b| {
                b.caps().admits(
                    1,
                    s.required_precision,
                    s.required_variant.as_deref(),
                )
            });
            if !any {
                bail!(
                    "tenant {} requires capabilities no backend offers",
                    s.name
                );
            }
        }
        // validate the specs once via a throwaway queue
        TenantQueue::new(&specs, cfg.admission, cfg.drain)?;
        Ok(TenantServeLoop { backends, specs, cfg })
    }

    pub fn backends(&self) -> &[Box<dyn ServeBackend>] {
        &self.backends
    }

    pub fn specs(&self) -> &[TenantSpec] {
        &self.specs
    }

    pub fn config(&self) -> &TenantServeConfig {
        &self.cfg
    }

    pub fn d_model(&self) -> usize {
        self.backends[0].caps().d_model
    }

    /// Capability-first candidate filter for one request: hard
    /// requirements only (rows vs batch ceiling, precision, variant,
    /// deadline feasibility at the backend's current throughput
    /// estimate and live fraction).  No load terms — scoring happens
    /// after, over the survivors.
    fn filter_candidates(
        &self,
        t: usize,
        rows: usize,
        queues: &[TenantQueue],
        est_ns_per_token: &[f64],
    ) -> Vec<usize> {
        let spec = &self.specs[t];
        (0..self.backends.len())
            .filter(|&b| {
                self.backends[b].caps().admits(
                    rows,
                    spec.required_precision,
                    spec.required_variant.as_deref(),
                )
            })
            .filter(|&b| match spec.deadline_ns {
                None => true,
                Some(dl) => queues[b].feasible(
                    t,
                    rows,
                    est_ns_per_token[b],
                    self.backends[b].live_fraction(),
                    dl,
                ),
            })
            .collect()
    }

    /// Score the filtered candidates: least estimated wait, computed
    /// as the policy-aware effective token backlog times the backend's
    /// effective per-token cost (1.0 before the first measurement, so
    /// cold backends compare by backlog alone).  Ties break to the
    /// lower index.
    fn score_candidates(
        &self,
        t: usize,
        rows: usize,
        candidates: &[usize],
        queues: &[TenantQueue],
        est_ns_per_token: &[f64],
    ) -> Option<usize> {
        candidates
            .iter()
            .map(|&b| {
                let live = self.backends[b].live_fraction();
                let eff = if est_ns_per_token[b] > 0.0 {
                    est_ns_per_token[b] / live.clamp(1e-9, 1.0)
                } else {
                    1.0
                };
                (b, queues[b].wait_tokens(t, rows) as f64 * eff)
            })
            .min_by(|a, b| {
                a.1.partial_cmp(&b.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(&b.0))
            })
            .map(|(b, _)| b)
    }

    /// Replay an arrival-sorted multi-tenant trace (module docs).
    /// Requests are identified by trace index in the report.
    pub fn run_trace(&self, trace: &[TenantRequest]) -> Result<TenantServeReport> {
        let d = self.d_model();
        let n_tenants = self.specs.len();
        for (i, r) in trace.iter().enumerate() {
            if r.tenant >= n_tenants {
                bail!("request {i} names tenant {} of {n_tenants}", r.tenant);
            }
            if r.x.shape.len() != 2 || r.x.shape[1] != d {
                bail!("request {i} shape {:?} (want (rows, {d}))", r.x.shape);
            }
            if r.x.shape[0] == 0 {
                bail!("request {i} has no rows");
            }
        }
        if trace.windows(2).any(|w| w[0].arrival_ns > w[1].arrival_ns) {
            bail!("trace must be sorted by arrival time");
        }

        let n_backends = self.backends.len();
        let mut queues: Vec<TenantQueue> = (0..n_backends)
            .map(|_| {
                TenantQueue::new(&self.specs, self.cfg.admission, self.cfg.drain)
                    .expect("specs validated at construction")
            })
            .collect();
        let batchers: Vec<MicroBatcher> = self
            .backends
            .iter()
            .map(|b| {
                MicroBatcher::new(
                    b.caps().max_batch_tokens,
                    self.cfg.latency_budget_ns,
                )
            })
            .collect();
        let mut est_ns_per_token = vec![0.0f64; n_backends];

        let mut per_tenant: Vec<ServeStats> =
            (0..n_tenants).map(|_| ServeStats::new()).collect();
        let mut global = ServeStats::new();
        let mut outputs: Vec<Option<TensorF>> = if self.cfg.capture_outputs {
            (0..trace.len()).map(|_| None).collect()
        } else {
            Vec::new()
        };
        let mut assigned: Vec<Option<usize>> = if self.cfg.capture_outputs {
            (0..trace.len()).map(|_| None).collect()
        } else {
            Vec::new()
        };

        let mut now: u64 = 0;
        let mut next = 0usize;
        loop {
            let queues_empty = queues.iter().all(|q| q.is_empty());
            if next >= trace.len() && queues_empty {
                break;
            }
            // 1. admit every arrival due at the current clock: filter
            // (capabilities, deadline) → score (least wait) → offer;
            // displaced requests are shed against their own tenants.
            while next < trace.len() && trace[next].arrival_ns <= now {
                let t = trace[next].tenant;
                let rows = trace[next].x.shape[0];
                per_tenant[t].offered += 1;
                let candidates =
                    self.filter_candidates(t, rows, &queues, &est_ns_per_token);
                // among capable backends, prefer ones that would not
                // refuse outright (Reject policy at a full lane)
                let open: Vec<usize> = candidates
                    .iter()
                    .copied()
                    .filter(|&b| !queues[b].will_reject(t))
                    .collect();
                if open.is_empty() {
                    per_tenant[t].shed += 1;
                    if let Some(b) = self.score_candidates(
                        t,
                        rows,
                        &candidates,
                        &queues,
                        &est_ns_per_token,
                    ) {
                        // capable but full under Reject: charge the
                        // refusal to the least-loaded capable queue's
                        // ledger (O(1), request never materialised)
                        queues[b].reject(t);
                    }
                    // no capable backend at all: a capability /
                    // feasibility mismatch — shed at the edge before
                    // any queue saw it
                } else {
                    let b = self
                        .score_candidates(
                            t,
                            rows,
                            &open,
                            &queues,
                            &est_ns_per_token,
                        )
                        .expect("open candidates are non-empty");
                    let dropped = queues[b].offer(
                        t,
                        ServeRequest {
                            id: next,
                            arrival_ns: trace[next].arrival_ns,
                            x: trace[next].x.clone(),
                        },
                    );
                    for (victim, _) in dropped {
                        per_tenant[victim].shed += 1;
                    }
                }
                next += 1;
            }
            let queues_empty = queues.iter().all(|q| q.is_empty());
            if queues_empty {
                if next < trace.len() {
                    now = trace[next].arrival_ns;
                    continue;
                }
                break;
            }
            // 2. dispatch decision per backend; among those triggering,
            // serve the one whose oldest request waited longest
            let drained = next >= trace.len();
            let mut chosen: Option<(usize, u64)> = None;
            for b in 0..n_backends {
                if batchers[b].should_dispatch(&queues[b], now, drained) {
                    let oldest = queues[b]
                        .oldest_arrival_ns()
                        .expect("dispatching queue is non-empty");
                    if chosen.map_or(true, |(_, o)| oldest < o) {
                        chosen = Some((b, oldest));
                    }
                }
            }
            let b = match chosen {
                Some((b, _)) => b,
                None => {
                    // sleep to the next actionable instant: the next
                    // arrival or the earliest lane deadline (both are
                    // ahead of `now`: due arrivals were admitted and an
                    // expired deadline dispatches above)
                    let mut wake = u64::MAX;
                    if next < trace.len() {
                        wake = trace[next].arrival_ns;
                    }
                    for (q, mb) in queues.iter().zip(&batchers) {
                        if let Some(dl) = mb.deadline_ns(q) {
                            wake = wake.min(dl);
                        }
                    }
                    now = now.max(wake);
                    continue;
                }
            };
            // 3. one forward step on the chosen backend
            let batch = batchers[b]
                .form(&mut queues[b], d)
                .expect("dispatch decision implies a non-empty queue");
            let dispatched_at = now;
            let t0 = Instant::now();
            let (combined, step) = self.backends[b].execute_forward(&batch.x)?;
            let wall = t0.elapsed().as_nanos() as u64;
            now += wall;
            global.record_batch(
                &step,
                batch.rows(),
                self.backends[b].caps().max_batch_tokens,
            );
            let per_tok = wall as f64 / batch.rows().max(1) as f64;
            est_ns_per_token[b] = if est_ns_per_token[b] == 0.0 {
                per_tok
            } else {
                0.7 * est_ns_per_token[b] + 0.3 * per_tok
            };
            let degraded = step.failed_chunks > 0 || step.degraded_tokens > 0;
            for slot in &batch.slots {
                let t = trace[slot.id].tenant;
                let stats = &mut per_tenant[t];
                if self.cfg.capture_outputs {
                    let rows = slot.rows.len();
                    let data = combined.data
                        [slot.rows.start * d..slot.rows.end * d]
                        .to_vec();
                    outputs[slot.id] = Some(TensorF::new(vec![rows, d], data));
                    assigned[slot.id] = Some(b);
                }
                if degraded {
                    // delivered renormalized, counted against quality
                    // (no retry path in the tenant loop yet)
                    stats.failed += 1;
                    continue;
                }
                stats.queue_wait.push(dispatched_at - slot.arrival_ns);
                stats.compute.push(wall);
                stats.total.push(now - slot.arrival_ns);
                if let Some(dl) = self.specs[t].deadline_ns {
                    if now - slot.arrival_ns > dl {
                        stats.slo_violations += 1;
                    }
                }
                stats.completed += 1;
                stats.tokens_served += slot.rows.len() as u64;
            }
        }
        // per-tenant peaks: a tenant's high-water lane depth, maximised
        // across the backend fleet; global peak: the deepest any one
        // backend's whole queue ever got
        for (t, stats) in per_tenant.iter_mut().enumerate() {
            stats.peak_queue_depth = queues
                .iter()
                .map(|q| q.peak_depth(t))
                .max()
                .unwrap_or(0);
            stats.wall_ns = now;
        }
        global.peak_queue_depth =
            queues.iter().map(|q| q.peak_total()).max().unwrap_or(0);
        global.wall_ns = now;
        // the global request ledger is exactly the sum of the tenant
        // ledgers — summed here so the invariant holds by construction
        // and the tests can assert it independently
        for s in &per_tenant {
            global.offered += s.offered;
            global.completed += s.completed;
            global.shed += s.shed;
            global.failed += s.failed;
            global.slo_violations += s.slo_violations;
            global.tokens_served += s.tokens_served;
            global.queue_wait.merge(&s.queue_wait);
            global.compute.merge(&s.compute);
            global.total.merge(&s.total);
        }
        Ok(TenantServeReport {
            global,
            per_tenant,
            tenants: self.specs.iter().map(|s| s.name.clone()).collect(),
            outputs,
            assigned_backend: assigned,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival_ns: u64, rows: usize) -> ServeRequest {
        ServeRequest {
            id,
            arrival_ns,
            x: TensorF::zeros(vec![rows, 4]),
        }
    }

    fn specs(weights: &[u64], depth: usize) -> Vec<TenantSpec> {
        weights
            .iter()
            .enumerate()
            .map(|(i, &w)| TenantSpec {
                weight: w,
                ..TenantSpec::new(&format!("t{i}"), depth)
            })
            .collect()
    }

    #[test]
    fn spec_validation_rejects_degenerate_tenants() {
        assert!(TenantQueue::new(
            &[],
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair
        )
        .is_err());
        let zero_w = vec![TenantSpec { weight: 0, ..TenantSpec::new("a", 4) }];
        assert!(TenantQueue::new(
            &zero_w,
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair
        )
        .is_err());
        let dup = vec![TenantSpec::new("a", 4), TenantSpec::new("a", 4)];
        assert!(TenantQueue::new(
            &dup,
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair
        )
        .is_err());
    }

    #[test]
    fn global_fifo_drains_in_arrival_order_across_lanes() {
        let mut q = TenantQueue::new(
            &specs(&[1, 1], 8),
            AdmissionPolicy::Reject,
            DrainPolicy::GlobalFifo,
        )
        .unwrap();
        q.offer(0, req(0, 0, 2));
        q.offer(1, req(1, 1, 2));
        q.offer(0, req(2, 2, 2));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_next())
            .map(|r| r.id)
            .collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn drr_shares_tokens_by_weight_under_backlog() {
        // both lanes saturated; weight 3 vs 1 should drain ~3:1 tokens
        let mut q = TenantQueue::new(
            &specs(&[3, 1], 64),
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair,
        )
        .unwrap();
        for i in 0..64 {
            q.offer(i % 2, req(i, 0, 1));
        }
        let mut served = [0usize; 2];
        for _ in 0..32 {
            let r = q.pop_next().unwrap();
            served[r.id % 2] += 1;
        }
        // lane 0 (weight 3) should have roughly 3× lane 1's service;
        // allow slack for round-robin granularity
        assert!(
            served[0] >= 2 * served[1],
            "weighted share not honoured: {served:?}"
        );
        assert!(served[1] > 0, "low-weight lane must not starve");
    }

    #[test]
    fn drr_never_starves_a_backlogged_lane() {
        let mut q = TenantQueue::new(
            &specs(&[1000, 1], 64),
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair,
        )
        .unwrap();
        for i in 0..32 {
            q.offer(0, req(i, 0, 4));
        }
        q.offer(1, req(32, 0, 4));
        let mut saw_lane1 = false;
        for _ in 0..33 {
            if let Some(r) = q.pop_next() {
                if r.id == 32 {
                    saw_lane1 = true;
                }
            }
        }
        assert!(saw_lane1, "weight-1 lane starved by weight-1000 lane");
        assert!(q.is_empty());
    }

    #[test]
    fn weighted_fair_sheds_lane_local_but_fifo_sheds_cross_tenant() {
        // WFQ: tenant 0 flooding its full lane only displaces itself
        let mut wfq = TenantQueue::new(
            &specs(&[1, 1], 2),
            AdmissionPolicy::ShedOldest,
            DrainPolicy::WeightedFair,
        )
        .unwrap();
        wfq.offer(1, req(0, 0, 1));
        for i in 1..6 {
            let dropped = wfq.offer(0, req(i, i as u64, 1));
            assert!(dropped.iter().all(|(t, _)| *t == 0));
        }
        assert_eq!(wfq.lane_len(1), 1, "victim's request survived");
        assert_eq!(wfq.ledger(1).shed, 0);
        // FIFO: the shared bound lets the flood displace tenant 1
        let mut fifo = TenantQueue::new(
            &specs(&[1, 1], 2),
            AdmissionPolicy::ShedOldest,
            DrainPolicy::GlobalFifo,
        )
        .unwrap();
        fifo.offer(1, req(0, 0, 1));
        for i in 1..6 {
            fifo.offer(0, req(i, i as u64, 1));
        }
        assert_eq!(
            fifo.ledger(1).shed,
            1,
            "heavy hitter should have displaced the victim's request"
        );
    }

    #[test]
    fn lane_ledgers_conserve_and_sum() {
        for policy in [DrainPolicy::GlobalFifo, DrainPolicy::WeightedFair] {
            for admission in
                [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest]
            {
                let mut q =
                    TenantQueue::new(&specs(&[2, 1, 1], 3), admission, policy)
                        .unwrap();
                let mut state = 7u64;
                let mut rng = move || {
                    state = state
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    (state >> 33) as usize
                };
                for i in 0..300 {
                    let t = rng() % 3;
                    match rng() % 4 {
                        0 | 1 => {
                            if q.will_reject(t) {
                                q.reject(t);
                            } else {
                                q.offer(t, req(i, i as u64, 1 + rng() % 4));
                            }
                        }
                        2 => {
                            q.pop_next();
                        }
                        _ => q.reject(t),
                    }
                    let mut sum = LaneLedger::default();
                    for t in 0..3 {
                        let l = q.ledger(t);
                        assert_eq!(
                            l.offered,
                            l.popped + l.shed + l.queued,
                            "{policy:?}/{admission:?} lane {t} broke at op {i}"
                        );
                        sum.offered += l.offered;
                        sum.popped += l.popped;
                        sum.shed += l.shed;
                        sum.queued += l.queued;
                    }
                    assert_eq!(sum.queued, q.total_len() as u64);
                    assert_eq!(
                        sum.offered,
                        sum.popped + sum.shed + sum.queued
                    );
                    // cached token counts stay exact under every
                    // interleaving (same invariant as RequestQueue)
                    let recompute: usize = (0..3)
                        .map(|t| {
                            q.lanes[t]
                                .queue
                                .iter()
                                .map(|r| r.rows())
                                .sum::<usize>()
                        })
                        .sum();
                    assert_eq!(q.depth_tokens(), recompute);
                }
            }
        }
    }

    #[test]
    fn wait_tokens_scales_with_service_share() {
        let mut q = TenantQueue::new(
            &specs(&[3, 1], 16),
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair,
        )
        .unwrap();
        for i in 0..4 {
            q.offer(0, req(i, 0, 2)); // lane 0: 8 tokens
            q.offer(1, req(10 + i, 0, 2)); // lane 1: 8 tokens
        }
        // lane 0 holds 8 tokens at share 3/4 → effective wait ≈ 13;
        // lane 1 holds 8 tokens at share 1/4 → effective wait ≈ 40
        let w0 = q.wait_tokens(0, 2);
        let w1 = q.wait_tokens(1, 2);
        assert!(w0 < w1, "higher weight must see shorter effective wait");
        assert_eq!(w0, 14); // ceil((8+2) * 4/3)
        assert_eq!(w1, 40); // (8+2) * 4/1
        // feasibility follows the same model
        assert!(q.feasible(0, 2, 100.0, 1.0, 1_500));
        assert!(!q.feasible(1, 2, 100.0, 1.0, 1_500));
        // global FIFO sees the whole shared backlog either way
        let mut f = TenantQueue::new(
            &specs(&[3, 1], 16),
            AdmissionPolicy::Reject,
            DrainPolicy::GlobalFifo,
        )
        .unwrap();
        for i in 0..4 {
            f.offer(0, req(i, 0, 2));
            f.offer(1, req(10 + i, 0, 2));
        }
        assert_eq!(f.wait_tokens(0, 2), 18);
        assert_eq!(f.wait_tokens(0, 2), f.wait_tokens(1, 2));
    }

    #[test]
    fn batch_source_contract_holds_for_tenant_queue() {
        let mut q = TenantQueue::new(
            &specs(&[1, 1], 8),
            AdmissionPolicy::Reject,
            DrainPolicy::WeightedFair,
        )
        .unwrap();
        assert!(q.is_empty());
        assert!(q.oldest_arrival_ns().is_none());
        assert!(q.next_rows().is_none());
        q.offer(0, req(0, 5, 3));
        q.offer(1, req(1, 2, 2));
        assert_eq!(q.depth_tokens(), 5);
        assert_eq!(q.oldest_arrival_ns(), Some(2));
        // next_rows describes exactly what pop_next returns
        let rows = q.next_rows().unwrap();
        let popped = q.pop_next().unwrap();
        assert_eq!(popped.rows(), rows);
        // an offer invalidates a cached selection
        q.next_rows();
        q.offer(0, req(2, 9, 1));
        let rows = q.next_rows().unwrap();
        assert_eq!(q.pop_next().unwrap().rows(), rows);
    }
}
