//! Continuous micro-batching: coalesce queued requests into
//! engine-sized token batches under a latency budget.
//!
//! The dispatch rule is the classic two-trigger one: ship a batch the
//! moment enough tokens are queued to fill the engine
//! (`max_tokens`), *or* the moment the oldest queued request's
//! deadline slack runs out (`latency_budget_ns` past its arrival) —
//! whichever comes first.  Requests are taken whole (a request's rows
//! must land in one step so its outputs scatter back in one piece),
//! FIFO, and every batch carries a row→request map
//! ([`BatchSlot`]) so the combined engine output is scattered back to
//! its owners.

use std::ops::Range;

use crate::runtime::TensorF;
use crate::serve::queue::{RequestId, RequestQueue, ServeRequest};

/// Anything the micro-batcher can drain: the single global
/// [`RequestQueue`], or the multi-tenant
/// [`TenantQueue`](crate::serve::TenantQueue) whose `pop_next` follows
/// its drain policy (deficit-round-robin or global FIFO) instead of
/// plain FIFO order.
pub trait BatchSource {
    fn is_empty(&self) -> bool;
    /// Total queued tokens across every backing lane.
    fn depth_tokens(&self) -> usize;
    /// Arrival stamp of the longest-waiting queued request (the
    /// latency-budget dispatch trigger watches this).
    fn oldest_arrival_ns(&self) -> Option<u64>;
    /// Rows of the request the next [`pop_next`](Self::pop_next) will
    /// return.  Takes `&mut self` because choosing the next request may
    /// advance scheduler state (e.g. DRR deficit replenishment).
    fn next_rows(&mut self) -> Option<usize>;
    /// Pop the request [`next_rows`](Self::next_rows) described.
    fn pop_next(&mut self) -> Option<ServeRequest>;
}

impl BatchSource for RequestQueue {
    fn is_empty(&self) -> bool {
        RequestQueue::is_empty(self)
    }

    fn depth_tokens(&self) -> usize {
        RequestQueue::depth_tokens(self)
    }

    fn oldest_arrival_ns(&self) -> Option<u64> {
        RequestQueue::oldest_arrival_ns(self)
    }

    fn next_rows(&mut self) -> Option<usize> {
        self.front().map(|r| r.rows())
    }

    fn pop_next(&mut self) -> Option<ServeRequest> {
        self.pop()
    }
}

/// Where one request's rows landed inside a coalesced batch.
#[derive(Clone, Debug)]
pub struct BatchSlot {
    pub id: RequestId,
    pub arrival_ns: u64,
    /// row range of this request inside the batch tensor
    pub rows: Range<usize>,
}

/// One coalesced engine batch plus the map that scatters its combined
/// output back per request.
pub struct MicroBatch {
    /// (rows, d) coalesced activations, requests concatenated FIFO
    pub x: TensorF,
    pub slots: Vec<BatchSlot>,
}

impl MicroBatch {
    pub fn rows(&self) -> usize {
        self.x.shape[0]
    }
}

/// The two-trigger dispatch policy (module docs).
#[derive(Clone, Debug)]
pub struct MicroBatcher {
    /// engine batch size: dispatch as soon as this many tokens queue up
    pub max_tokens: usize,
    /// deadline slack: dispatch a partial batch once the oldest request
    /// has waited this long
    pub latency_budget_ns: u64,
}

impl MicroBatcher {
    pub fn new(max_tokens: usize, latency_budget_ns: u64) -> Self {
        MicroBatcher { max_tokens: max_tokens.max(1), latency_budget_ns }
    }

    /// The oldest queued request's dispatch deadline.
    pub fn deadline_ns<S: BatchSource + ?Sized>(
        &self,
        queue: &S,
    ) -> Option<u64> {
        queue
            .oldest_arrival_ns()
            .map(|a| a.saturating_add(self.latency_budget_ns))
    }

    /// Should a batch be dispatched now?  `drained` marks that no more
    /// arrivals are coming (trace exhausted), so waiting for a fuller
    /// batch would only burn latency.
    pub fn should_dispatch<S: BatchSource + ?Sized>(
        &self,
        queue: &S,
        now_ns: u64,
        drained: bool,
    ) -> bool {
        if queue.is_empty() {
            return false;
        }
        drained
            || queue.depth_tokens() >= self.max_tokens
            || self.deadline_ns(queue).is_some_and(|d| now_ns >= d)
    }

    /// Pop whole requests in source order (FIFO for a [`RequestQueue`],
    /// policy order for a tenant front-end) until the next one would
    /// overflow `max_tokens`, concatenating their rows into one
    /// (rows, d) tensor.  The first request is always taken, so a
    /// request as large as the cap still ships alone.  `None` on an
    /// empty source.
    pub fn form<S: BatchSource + ?Sized>(
        &self,
        queue: &mut S,
        d: usize,
    ) -> Option<MicroBatch> {
        if queue.is_empty() {
            return None;
        }
        let mut data: Vec<f32> = Vec::new();
        let mut slots: Vec<BatchSlot> = Vec::new();
        let mut rows = 0usize;
        while let Some(next_rows) = queue.next_rows() {
            if !slots.is_empty() && rows + next_rows > self.max_tokens {
                break;
            }
            let req = queue.pop_next().expect("next_rows was Some");
            data.extend_from_slice(&req.x.data);
            slots.push(BatchSlot {
                id: req.id,
                arrival_ns: req.arrival_ns,
                rows: rows..rows + next_rows,
            });
            rows += next_rows;
            if rows >= self.max_tokens {
                break;
            }
        }
        Some(MicroBatch { x: TensorF::new(vec![rows, d], data), slots })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::queue::{AdmissionPolicy, ServeRequest};

    fn queue_with(rows: &[usize]) -> RequestQueue {
        let mut q = RequestQueue::new(64, AdmissionPolicy::Reject);
        for (i, &r) in rows.iter().enumerate() {
            let x = TensorF::new(
                vec![r, 2],
                (0..r * 2).map(|v| (i * 100 + v) as f32).collect(),
            );
            q.offer(ServeRequest { id: i, arrival_ns: 10 * i as u64, x });
        }
        q
    }

    #[test]
    fn dispatches_on_fill_or_deadline_or_drain() {
        let mb = MicroBatcher::new(8, 100);
        let empty = RequestQueue::new(4, AdmissionPolicy::Reject);
        assert!(!mb.should_dispatch(&empty, 1_000_000, true));

        let q = queue_with(&[3, 2]); // 5 tokens, oldest arrived at 0
        assert!(!mb.should_dispatch(&q, 50, false), "under fill + budget");
        assert!(mb.should_dispatch(&q, 100, false), "deadline expired");
        assert!(mb.should_dispatch(&q, 50, true), "trace drained");

        let full = queue_with(&[3, 2, 4]); // 9 >= 8 tokens
        assert!(mb.should_dispatch(&full, 0, false), "batch fills");
        assert_eq!(mb.deadline_ns(&full), Some(100));
    }

    #[test]
    fn form_coalesces_fifo_and_maps_rows_to_requests() {
        let mb = MicroBatcher::new(6, 0);
        let mut q = queue_with(&[3, 2, 4]);
        let b = mb.form(&mut q, 2).unwrap();
        // 3 + 2 fit; request 2 (4 rows) would overflow the 6-token cap
        assert_eq!(b.rows(), 5);
        assert_eq!(b.x.shape, vec![5, 2]);
        assert_eq!(b.slots.len(), 2);
        assert_eq!(b.slots[0].id, 0);
        assert_eq!(b.slots[0].rows, 0..3);
        assert_eq!(b.slots[1].id, 1);
        assert_eq!(b.slots[1].rows, 3..5);
        // rows land contiguously in request order
        assert_eq!(b.x.row(0), &[0.0, 1.0]);
        assert_eq!(b.x.row(3), &[100.0, 101.0]);
        // the overflowing request is still queued for the next batch
        assert_eq!(q.len(), 1);
        let b2 = mb.form(&mut q, 2).unwrap();
        assert_eq!(b2.slots[0].id, 2);
        assert_eq!(b2.rows(), 4);
        assert!(mb.form(&mut q, 2).is_none());
    }

    #[test]
    fn oversized_request_ships_alone() {
        let mb = MicroBatcher::new(4, 0);
        let mut q = queue_with(&[9, 1]);
        let b = mb.form(&mut q, 2).unwrap();
        assert_eq!(b.rows(), 9);
        assert_eq!(b.slots.len(), 1);
        assert_eq!(q.len(), 1);
    }
}
