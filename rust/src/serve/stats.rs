//! Serving SLO telemetry: per-request latency histograms, achieved
//! throughput, batch occupancy, backpressure and SLO-violation
//! counters — all published into the unified metrics registry.
//!
//! Latency is decomposed the way an SLO dashboard wants it:
//! `queue_wait` (arrival → batch dispatch), `compute` (the batch's
//! engine wall, shared by every request riding it) and `total`
//! (arrival → outputs scattered back).  All three are exact sample
//! histograms ([`Histogram`]) so p50/p95/p99 are true order
//! statistics, not bucket interpolations.
//!
//! [`ServeStats::publish`] writes everything into a
//! [`crate::obs::Registry`] under the `serve_*` keys (latency
//! histograms merged sample-exactly, fault counters under the shared
//! `fault_*` keys), and [`ServeStats::summary_line`] is a *renderer
//! over the resulting snapshot* — the console line, the JSON snapshot
//! ([`crate::obs::Snapshot::to_json`]) and the Prometheus exposition
//! always show the same numbers.  The request ledger conserves:
//! `offered == completed + shed + failed`, with `slo_violations`
//! counting completed requests that still blew `deadline_ns`.

use crate::coordinator::scheduler::{PhaseNanos, StepStats};
use crate::obs::{Registry, Snapshot};
use crate::util::bench::Histogram;

/// Aggregated telemetry of one [`ServeLoop`](crate::serve::ServeLoop)
/// trace replay.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// arrival → batch dispatch, per completed request
    pub queue_wait: Histogram,
    /// engine wall of the batch a request rode, per completed request
    pub compute: Histogram,
    /// arrival → output scattered back, per completed request
    pub total: Histogram,
    /// requests the trace offered to admission control — the ledger
    /// total: `offered == completed + shed + failed`
    pub offered: u64,
    pub completed: u64,
    /// requests dropped by admission control (reject or shed-oldest)
    pub shed: u64,
    /// completed requests whose total latency exceeded the configured
    /// `deadline_ns` (0 when no deadline is set) — delivered, but
    /// counted against the latency SLO
    pub slo_violations: u64,
    pub tokens_served: u64,
    pub batches: u64,
    /// sum of batch rows (numerator of [`batch_occupancy`](Self::batch_occupancy))
    pub batch_tokens: u64,
    /// sum of batch capacities (`batches * max_tokens`)
    pub batch_capacity: u64,
    /// serve-clock time from first arrival consideration to last combine
    pub wall_ns: u64,
    /// high-water queue depth (bounded-memory witness)
    pub peak_queue_depth: usize,
    /// engine phase nanoseconds summed over every dispatched batch
    pub phases: PhaseNanos,
    /// degraded-batch re-offers (retry-with-backoff attempts)
    pub retried: u64,
    /// requests whose final attempt still rode a degraded batch — their
    /// (renormalized) outputs are delivered but they don't count as
    /// `completed`, so `offered == completed + shed + failed` holds
    pub failed: u64,
    /// expert chunks lost to injected faults across all batches
    pub failed_chunks: u64,
    /// failed routes recovered onto the token's other selected experts
    pub redispatched_routes: u64,
    /// token rows combined with renormalized (partial) gate mass
    pub degraded_tokens: u64,
    /// total eq-1 gate mass renormalized away across all batches
    pub renorm_mass_lost: f64,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one dispatched batch's engine telemetry in (per-request
    /// latency attribution happens in the serve loop).
    pub fn record_batch(
        &mut self,
        step: &StepStats,
        batch_rows: usize,
        max_tokens: usize,
    ) {
        self.batches += 1;
        self.batch_tokens += batch_rows as u64;
        // an oversized single request ships alone in a batch larger
        // than the cap; count its true size as the capacity so the
        // occupancy fraction stays <= 1
        self.batch_capacity += max_tokens.max(batch_rows) as u64;
        self.phases.route += step.phases.route;
        self.phases.gather += step.phases.gather;
        self.phases.compute += step.phases.compute;
        self.phases.combine += step.phases.combine;
        self.phases.overlap_ns += step.phases.overlap_ns;
        self.failed_chunks += step.failed_chunks as u64;
        self.redispatched_routes += step.redispatched_routes as u64;
        self.degraded_tokens += step.degraded_tokens as u64;
        self.renorm_mass_lost += step.renorm_mass_lost;
    }

    /// Achieved throughput over the whole replay (serve-clock seconds).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.tokens_served as f64 / (self.wall_ns as f64 / 1e9)
        }
    }

    /// Mean fraction of the engine batch the micro-batcher filled.
    pub fn batch_occupancy(&self) -> f64 {
        if self.batch_capacity == 0 {
            0.0
        } else {
            self.batch_tokens as f64 / self.batch_capacity as f64
        }
    }

    /// Publish into the unified registry: the request ledger and batch
    /// counters under `serve_*` keys, the latency histograms merged
    /// sample-exactly (`serve_queue_wait_ns` / `serve_compute_ns` /
    /// `serve_total_ns`), the summed engine phases as
    /// `step_phase_ns{phase=...}`, and the fault tally under the shared
    /// `fault_*` keys.  `peak_queue_depth` is a high-water mark, not a
    /// flow: it goes in as a max-combining gauge
    /// ([`Registry::gauge_max`]) so re-publishing or merging replays is
    /// idempotent instead of summing peaks.
    pub fn publish(&self, reg: &mut Registry) {
        self.publish_with(reg, &[]);
    }

    /// [`publish`](Self::publish) under extra labels — the multi-tenant
    /// front-end publishes each tenant's ledger as
    /// `serve_*{tenant="..."}` so per-tenant and global series coexist
    /// in one registry.
    pub fn publish_with(&self, reg: &mut Registry, labels: &[(&str, &str)]) {
        let k = |name: &str| crate::obs::key(name, labels);
        reg.counter_add(&k("serve_offered"), self.offered);
        reg.counter_add(&k("serve_completed"), self.completed);
        reg.counter_add(&k("serve_shed"), self.shed);
        reg.counter_add(&k("serve_failed"), self.failed);
        reg.counter_add(&k("serve_retried"), self.retried);
        reg.counter_add(&k("serve_slo_violations"), self.slo_violations);
        reg.counter_add(&k("serve_tokens_served"), self.tokens_served);
        reg.counter_add(&k("serve_batches"), self.batches);
        reg.counter_add(&k("serve_batch_tokens"), self.batch_tokens);
        reg.counter_add(&k("serve_batch_capacity"), self.batch_capacity);
        reg.counter_add(&k("serve_wall_ns"), self.wall_ns);
        reg.gauge_max(
            &k("serve_peak_queue_depth"),
            self.peak_queue_depth as f64,
        );
        reg.merge_hist(&k("serve_queue_wait_ns"), &self.queue_wait);
        reg.merge_hist(&k("serve_compute_ns"), &self.compute);
        reg.merge_hist(&k("serve_total_ns"), &self.total);
        self.phases.publish(reg);
        reg.counter_add(&k("fault_failed_chunks"), self.failed_chunks);
        reg.counter_add(
            &k("fault_redispatched_routes"),
            self.redispatched_routes,
        );
        reg.counter_add(&k("fault_degraded_tokens"), self.degraded_tokens);
        reg.gauge_add(&k("fault_renorm_mass_lost"), self.renorm_mass_lost);
    }

    /// One-line SLO summary — the single place the serve report format
    /// lives (demos, benches and `repro serve` all print this).  A
    /// renderer over the registry: publishes into a fresh [`Registry`]
    /// and formats the snapshot via
    /// [`render_summary`](Self::render_summary).
    pub fn summary_line(&self) -> String {
        let mut reg = Registry::new();
        self.publish(&mut reg);
        Self::render_summary(&reg.snapshot())
    }

    /// Format the serve summary from a registry snapshot (the `serve_*`
    /// / `fault_*` keys [`publish`](Self::publish) writes) — any
    /// aggregated snapshot renders with the same line, not just a
    /// single replay's.
    pub fn render_summary(s: &Snapshot) -> String {
        let wall_ns = s.counter("serve_wall_ns");
        let tokens = s.counter("serve_tokens_served");
        let tok_per_sec = if wall_ns == 0 {
            0.0
        } else {
            tokens as f64 / (wall_ns as f64 / 1e9)
        };
        let cap = s.counter("serve_batch_capacity");
        let occupancy = if cap == 0 {
            0.0
        } else {
            s.counter("serve_batch_tokens") as f64 / cap as f64
        };
        let queue = s.hist("serve_queue_wait_ns").cloned().unwrap_or_default();
        let total = s.hist("serve_total_ns").cloned().unwrap_or_default();
        let mut line = format!(
            "served {:>5} req ({:>4} shed)  {:>9.0} tok/s  occupancy {:>3.0}%  \
             queue p50/p99 {:>8.3}/{:>8.3}ms  total p50/p99 {:>8.3}/{:>8.3}ms",
            s.counter("serve_completed"),
            s.counter("serve_shed"),
            tok_per_sec,
            occupancy * 100.0,
            queue.p50_ns as f64 / 1e6,
            queue.p99_ns as f64 / 1e6,
            total.p50_ns as f64 / 1e6,
            total.p99_ns as f64 / 1e6,
        );
        let failed = s.counter("serve_failed");
        let retried = s.counter("serve_retried");
        let failed_chunks = s.counter("fault_failed_chunks");
        if failed > 0 || failed_chunks > 0 || retried > 0 {
            line.push_str(&format!(
                "  faults: {} failed / {} retried / {} chunks / {} tok degraded",
                failed,
                retried,
                failed_chunks,
                s.counter("fault_degraded_tokens"),
            ));
        }
        let slo = s.counter("serve_slo_violations");
        if slo > 0 {
            line.push_str(&format!("  slo: {slo} violated"));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics_handle_empty_and_filled_states() {
        let mut s = ServeStats::new();
        assert_eq!(s.tokens_per_sec(), 0.0);
        assert_eq!(s.batch_occupancy(), 0.0);
        assert!(s.summary_line().contains("0 req"));

        let step = StepStats {
            phases: PhaseNanos {
                compute: 500,
                combine: 100,
                ..Default::default()
            },
            failed_chunks: 2,
            redispatched_routes: 1,
            degraded_tokens: 3,
            renorm_mass_lost: 0.25,
            ..Default::default()
        };
        s.record_batch(&step, 24, 32);
        s.record_batch(&step, 8, 32);
        s.tokens_served = 32;
        s.wall_ns = 1_000_000_000; // 1s of serve clock
        assert_eq!(s.batches, 2);
        assert!((s.batch_occupancy() - 0.5).abs() < 1e-9);
        assert!((s.tokens_per_sec() - 32.0).abs() < 1e-9);
        assert_eq!(s.phases.compute, 1000);
        assert_eq!(s.phases.combine, 200);
        assert_eq!(s.failed_chunks, 4);
        assert_eq!(s.redispatched_routes, 2);
        assert_eq!(s.degraded_tokens, 6);
        assert!((s.renorm_mass_lost - 0.5).abs() < 1e-12);
        assert!(s.summary_line().contains("faults:"));

        // an oversized single-request batch counts its true size as
        // capacity, so mean occupancy cannot exceed 1
        s.record_batch(&step, 48, 32);
        assert!(s.batch_occupancy() <= 1.0);
    }

    #[test]
    fn summary_line_is_a_renderer_over_the_registry_snapshot() {
        let mut s = ServeStats::new();
        s.offered = 10;
        s.completed = 7;
        s.shed = 2;
        s.failed = 1;
        s.retried = 3;
        s.slo_violations = 2;
        s.tokens_served = 140;
        s.batches = 4;
        s.batch_tokens = 140;
        s.batch_capacity = 160;
        s.wall_ns = 2_000_000;
        for ns in [1_000_000u64, 2_000_000, 3_000_000] {
            s.queue_wait.push(ns);
            s.compute.push(ns / 2);
            s.total.push(ns * 2);
        }
        let mut reg = Registry::new();
        s.publish(&mut reg);
        let snap = reg.snapshot();
        // the console line and the snapshot agree by construction
        assert_eq!(s.summary_line(), ServeStats::render_summary(&snap));
        assert!(s.summary_line().contains("faults: 1 failed / 3 retried"));
        assert!(s.summary_line().contains("slo: 2 violated"));
        // ledger keys round-trip
        assert_eq!(snap.counter("serve_offered"), 10);
        assert_eq!(
            snap.counter("serve_offered"),
            snap.counter("serve_completed")
                + snap.counter("serve_shed")
                + snap.counter("serve_failed")
        );
        assert_eq!(snap.hist("serve_total_ns").unwrap().count, 3);
        // publishing twice accumulates (counters are monotonic sums)
        s.publish(&mut reg);
        assert_eq!(reg.snapshot().counter("serve_offered"), 20);
    }

    #[test]
    fn publish_with_labels_writes_tenant_scoped_keys() {
        let mut s = ServeStats::new();
        s.offered = 5;
        s.completed = 4;
        s.shed = 1;
        s.peak_queue_depth = 6;
        s.total.push(1_000);
        let mut reg = Registry::new();
        s.publish_with(&mut reg, &[("tenant", "acme")]);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("serve_offered{tenant=\"acme\"}"), 5);
        assert_eq!(snap.counter("serve_offered"), 0);
        assert_eq!(snap.gauge("serve_peak_queue_depth{tenant=\"acme\"}"), 6.0);
        assert_eq!(
            snap.hist("serve_total_ns{tenant=\"acme\"}").unwrap().count,
            1
        );
    }
}
