//! Serving SLO telemetry: per-request latency histograms, achieved
//! throughput, batch occupancy and backpressure counters.
//!
//! Latency is decomposed the way an SLO dashboard wants it:
//! `queue_wait` (arrival → batch dispatch), `compute` (the batch's
//! engine wall, shared by every request riding it) and `total`
//! (arrival → outputs scattered back).  All three are exact sample
//! histograms ([`Histogram`]) so p50/p95/p99 are true order
//! statistics, not bucket interpolations.

use crate::coordinator::scheduler::{PhaseNanos, StepStats};
use crate::util::bench::Histogram;

/// Aggregated telemetry of one [`ServeLoop`](crate::serve::ServeLoop)
/// trace replay.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// arrival → batch dispatch, per completed request
    pub queue_wait: Histogram,
    /// engine wall of the batch a request rode, per completed request
    pub compute: Histogram,
    /// arrival → output scattered back, per completed request
    pub total: Histogram,
    pub completed: u64,
    /// requests dropped by admission control (reject or shed-oldest)
    pub shed: u64,
    pub tokens_served: u64,
    pub batches: u64,
    /// sum of batch rows (numerator of [`batch_occupancy`](Self::batch_occupancy))
    pub batch_tokens: u64,
    /// sum of batch capacities (`batches * max_tokens`)
    pub batch_capacity: u64,
    /// serve-clock time from first arrival consideration to last combine
    pub wall_ns: u64,
    /// high-water queue depth (bounded-memory witness)
    pub peak_queue_depth: usize,
    /// engine phase nanoseconds summed over every dispatched batch
    pub phases: PhaseNanos,
    /// degraded-batch re-offers (retry-with-backoff attempts)
    pub retried: u64,
    /// requests whose final attempt still rode a degraded batch — their
    /// (renormalized) outputs are delivered but they don't count as
    /// `completed`, so `offered == completed + shed + failed` holds
    pub failed: u64,
    /// expert chunks lost to injected faults across all batches
    pub failed_chunks: u64,
    /// failed routes recovered onto the token's other selected experts
    pub redispatched_routes: u64,
    /// token rows combined with renormalized (partial) gate mass
    pub degraded_tokens: u64,
    /// total eq-1 gate mass renormalized away across all batches
    pub renorm_mass_lost: f64,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one dispatched batch's engine telemetry in (per-request
    /// latency attribution happens in the serve loop).
    pub fn record_batch(
        &mut self,
        step: &StepStats,
        batch_rows: usize,
        max_tokens: usize,
    ) {
        self.batches += 1;
        self.batch_tokens += batch_rows as u64;
        // an oversized single request ships alone in a batch larger
        // than the cap; count its true size as the capacity so the
        // occupancy fraction stays <= 1
        self.batch_capacity += max_tokens.max(batch_rows) as u64;
        self.phases.route += step.phases.route;
        self.phases.gather += step.phases.gather;
        self.phases.compute += step.phases.compute;
        self.phases.combine += step.phases.combine;
        self.phases.overlap_ns += step.phases.overlap_ns;
        self.failed_chunks += step.failed_chunks as u64;
        self.redispatched_routes += step.redispatched_routes as u64;
        self.degraded_tokens += step.degraded_tokens as u64;
        self.renorm_mass_lost += step.renorm_mass_lost;
    }

    /// Achieved throughput over the whole replay (serve-clock seconds).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.tokens_served as f64 / (self.wall_ns as f64 / 1e9)
        }
    }

    /// Mean fraction of the engine batch the micro-batcher filled.
    pub fn batch_occupancy(&self) -> f64 {
        if self.batch_capacity == 0 {
            0.0
        } else {
            self.batch_tokens as f64 / self.batch_capacity as f64
        }
    }

    /// One-line SLO summary — the single place the serve report format
    /// lives (demos, benches and `repro serve` all print this).
    pub fn summary_line(&self) -> String {
        let queue = self.queue_wait.percentiles(&[0.50, 0.99]);
        let total = self.total.percentiles(&[0.50, 0.99]);
        let mut line = format!(
            "served {:>5} req ({:>4} shed)  {:>9.0} tok/s  occupancy {:>3.0}%  \
             queue p50/p99 {:>8.3}/{:>8.3}ms  total p50/p99 {:>8.3}/{:>8.3}ms",
            self.completed,
            self.shed,
            self.tokens_per_sec(),
            self.batch_occupancy() * 100.0,
            queue[0] as f64 / 1e6,
            queue[1] as f64 / 1e6,
            total[0] as f64 / 1e6,
            total[1] as f64 / 1e6,
        );
        if self.failed > 0 || self.failed_chunks > 0 || self.retried > 0 {
            line.push_str(&format!(
                "  faults: {} failed / {} retried / {} chunks / {} tok degraded",
                self.failed, self.retried, self.failed_chunks, self.degraded_tokens,
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics_handle_empty_and_filled_states() {
        let mut s = ServeStats::new();
        assert_eq!(s.tokens_per_sec(), 0.0);
        assert_eq!(s.batch_occupancy(), 0.0);
        assert!(s.summary_line().contains("0 req"));

        let step = StepStats {
            phases: PhaseNanos {
                compute: 500,
                combine: 100,
                ..Default::default()
            },
            failed_chunks: 2,
            redispatched_routes: 1,
            degraded_tokens: 3,
            renorm_mass_lost: 0.25,
            ..Default::default()
        };
        s.record_batch(&step, 24, 32);
        s.record_batch(&step, 8, 32);
        s.tokens_served = 32;
        s.wall_ns = 1_000_000_000; // 1s of serve clock
        assert_eq!(s.batches, 2);
        assert!((s.batch_occupancy() - 0.5).abs() < 1e-9);
        assert!((s.tokens_per_sec() - 32.0).abs() < 1e-9);
        assert_eq!(s.phases.compute, 1000);
        assert_eq!(s.phases.combine, 200);
        assert_eq!(s.failed_chunks, 4);
        assert_eq!(s.redispatched_routes, 2);
        assert_eq!(s.degraded_tokens, 6);
        assert!((s.renorm_mass_lost - 0.5).abs() < 1e-12);
        assert!(s.summary_line().contains("faults:"));

        // an oversized single-request batch counts its true size as
        // capacity, so mean occupancy cannot exceed 1
        s.record_batch(&step, 48, 32);
        assert!(s.batch_occupancy() <= 1.0);
    }
}
