//! The serve loop: continuous micro-batching inference on the
//! persistent engine.
//!
//! [`ServeLoop::run_trace`] replays an arrival-stamped request trace
//! against the frozen model.  Time is a **hybrid serve clock**: arrival
//! stamps come from the (deterministic, seeded) trace, while each
//! dispatched batch advances the clock by its *measured* engine wall —
//! so queueing dynamics are exactly reproducible given a trace, compute
//! cost is real, and open-loop semantics hold: arrivals keep landing
//! (and shedding) while a batch computes, no matter how overloaded the
//! engine is.  The loop between batches:
//!
//! 1. admit every arrival due at the current clock (admission control
//!    may shed — [`RequestQueue`]);
//! 2. if the queue is idle, jump the clock to the next arrival;
//! 3. ask the [`MicroBatcher`] whether to dispatch (batch full, oldest
//!    deadline blown, or trace drained); if not, advance the clock to
//!    the earlier of next-arrival and oldest-deadline and retry;
//! 4. form the batch, run one forward-only step
//!    ([`Scheduler::execute_forward`] — no gating noise, no trainer
//!    bookkeeping, pooled arenas reused across steps), advance the
//!    clock by the measured wall, scatter outputs back per request via
//!    the batch's row map, and record SLO samples.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::scheduler::ExpertWeights;
use crate::coordinator::{Router, Scheduler};
use crate::kernels::quant::{Precision, QuantizedExpertWeights};
use crate::runtime::{ModelConfig, TensorF};
use crate::serve::backend::{EngineBackend, ServeBackend};
use crate::serve::batcher::MicroBatcher;
use crate::serve::queue::{AdmissionPolicy, RequestQueue, ServeRequest};
use crate::serve::stats::ServeStats;
use crate::train::checkpoint;
use crate::train::trainer::StreamedTrainState;

/// Serving-runtime knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// admission-queue depth bound (requests)
    pub queue_depth: usize,
    pub policy: AdmissionPolicy,
    /// engine batch size the micro-batcher fills toward (tokens)
    pub max_batch_tokens: usize,
    /// dispatch a partial batch once the oldest request waited this long
    pub latency_budget_ns: u64,
    /// keep per-request outputs in the report (differential tests /
    /// actual serving); off for pure load measurement
    pub capture_outputs: bool,
    /// re-offer a request whose batch was degraded by fault recovery,
    /// up to this many times (0 = serve the degraded output as-is)
    pub retry_max: u32,
    /// serve-clock delay before a degraded request is re-offered
    pub retry_backoff_ns: u64,
    /// per-request latency SLO; when set, arrivals that cannot meet it
    /// at the current (possibly fault-degraded) throughput estimate are
    /// shed up-front ([`RequestQueue::feasible`])
    pub deadline_ns: Option<u64>,
    /// expert-FFN numeric width: [`Precision::F32`] serves the
    /// checkpoint weights bit-exactly; [`Precision::Int8`] quantizes
    /// them at load (per-output-channel symmetric, the f32 originals
    /// are kept untouched) and serves within
    /// [`crate::kernels::quant::SERVE_REL_ERR_BUDGET`] of the f32
    /// outputs.  Int8 requires a natively-streaming configuration —
    /// [`ServeLoop::new`] rejects others up front.
    pub precision: Precision,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_depth: 256,
            policy: AdmissionPolicy::Reject,
            max_batch_tokens: 1024,
            latency_budget_ns: 1_000_000, // 1ms
            capture_outputs: false,
            retry_max: 0,
            retry_backoff_ns: 0,
            deadline_ns: None,
            precision: Precision::F32,
        }
    }
}

/// One trace entry: when the request arrives (serve clock, ns) and its
/// ragged (rows, d) activations.
pub struct TimedRequest {
    pub arrival_ns: u64,
    pub x: TensorF,
}

/// Result of one trace replay.
pub struct ServeReport {
    pub stats: ServeStats,
    /// per-trace-index outputs when `capture_outputs` was set (`None`
    /// for requests admission control shed); empty otherwise
    pub outputs: Vec<Option<TensorF>>,
}

/// Continuous micro-batching inference runtime over a frozen MoE.
/// Executes through a single [`EngineBackend`] — the same validation
/// and dispatch the loop always had, factored behind [`ServeBackend`]
/// so the multi-tenant front-end can route across a fleet of these.
pub struct ServeLoop {
    backend: EngineBackend,
    cfg: ServeConfig,
}

impl ServeLoop {
    /// Serve the given frozen router + expert weights on `sched`'s
    /// persistent engine.
    pub fn new(
        sched: Scheduler,
        router: Router,
        weights: Vec<ExpertWeights>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let backend = EngineBackend::new(
            "engine",
            "base",
            sched,
            router,
            weights,
            cfg.precision,
            cfg.max_batch_tokens,
        )?;
        Ok(ServeLoop { backend, cfg })
    }

    /// Freeze a streamed training state (gating included) for serving.
    pub fn from_state(
        sched: Scheduler,
        state: StreamedTrainState,
        cfg: ServeConfig,
    ) -> Result<Self> {
        Self::new(sched, state.router, state.weights, cfg)
    }

    /// Load a [`checkpoint::save_streamed`] checkpoint and serve it.
    pub fn from_checkpoint(
        sched: Scheduler,
        path: &std::path::Path,
        cfg_name: &str,
        model: &ModelConfig,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let state = checkpoint::load_streamed(path, cfg_name, model)?;
        Self::from_state(sched, state, cfg)
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn d_model(&self) -> usize {
        self.backend.caps().d_model
    }

    /// The single engine backend this loop executes on.
    pub fn backend(&self) -> &EngineBackend {
        &self.backend
    }

    /// The frozen f32 expert weights (always the checkpoint values —
    /// int8 serving quantizes a *copy* at load, so these are unchanged
    /// under [`Precision::Int8`]; tests assert exactly that).
    pub fn weights(&self) -> &[ExpertWeights] {
        self.backend.weights()
    }

    /// The int8 weight twins when serving at [`Precision::Int8`].
    pub fn quantized_weights(&self) -> Option<&[QuantizedExpertWeights]> {
        self.backend.quantized_weights()
    }

    /// Drain the trace spans the underlying engine recorded across the
    /// batches served so far (empty unless the scheduler was built
    /// [`Scheduler::with_obs`]-enabled or `MOE_TRACE` is set).
    pub fn take_spans(&self) -> Vec<crate::obs::Span> {
        self.backend.take_spans()
    }

    /// Replay an arrival-sorted trace (module docs).  Requests are
    /// identified by trace index in the report.
    ///
    /// Under an active [`FaultPlan`](crate::coordinator::FaultPlan) the
    /// loop adds two recovery behaviours.  **Retry with backoff**: a
    /// request whose batch was degraded (lost chunks, renormalized
    /// combine) is re-offered `retry_backoff_ns` later, up to
    /// `retry_max` times; a request still degraded on its final attempt
    /// keeps its renormalized output but counts as `failed`, so
    /// `offered == completed + shed + failed` always holds.
    /// **Health-aware shedding**: when `deadline_ns` is set, each
    /// arrival is checked against the backlog at the EWMA-estimated
    /// per-token cost scaled by [`Scheduler::live_fraction`] — as fault
    /// recovery masks shards out, infeasible requests are shed at the
    /// edge instead of queueing to blow their SLO.
    pub fn run_trace(&self, trace: &[TimedRequest]) -> Result<ServeReport> {
        let d = self.d_model();
        for (i, r) in trace.iter().enumerate() {
            if r.x.shape.len() != 2 || r.x.shape[1] != d {
                bail!(
                    "request {i} shape {:?} (want (rows, {d}))",
                    r.x.shape
                );
            }
            if r.x.shape[0] == 0 {
                bail!("request {i} has no rows");
            }
        }
        if trace.windows(2).any(|w| w[0].arrival_ns > w[1].arrival_ns) {
            bail!("trace must be sorted by arrival time");
        }

        let mut queue = RequestQueue::new(self.cfg.queue_depth, self.cfg.policy);
        let batcher = MicroBatcher::new(
            self.cfg.max_batch_tokens,
            self.cfg.latency_budget_ns,
        );
        let mut stats = ServeStats::new();
        let mut outputs: Vec<Option<TensorF>> = if self.cfg.capture_outputs {
            (0..trace.len()).map(|_| None).collect()
        } else {
            Vec::new()
        };

        // retry-with-backoff state: attempts consumed per trace index,
        // and degraded requests parked until their backoff expires
        // (`due_ns` is nondecreasing — the clock only moves forward and
        // the backoff is constant — so a deque stays sorted)
        let mut attempts: Vec<u32> = vec![0; trace.len()];
        let mut retries: std::collections::VecDeque<(u64, ServeRequest)> =
            std::collections::VecDeque::new();
        // EWMA of measured engine cost, the throughput side of the
        // deadline-feasibility check (0 until the first batch lands)
        let mut est_ns_per_token: f64 = 0.0;

        let mut now: u64 = 0;
        let mut next = 0usize; // next trace entry not yet offered
        while next < trace.len() || !queue.is_empty() || !retries.is_empty() {
            // 1. admit everything due at the current clock; dropped
            // requests are counted by the queue and their outputs stay
            // None in the report.  Backed-off retries re-enter through
            // the same admission control as fresh arrivals.
            let live = self.backend.live_fraction();
            while retries.front().is_some_and(|(due, _)| *due <= now) {
                let (_, req) = retries.pop_front().expect("front was Some");
                let infeasible = self.cfg.deadline_ns.is_some_and(|dl| {
                    !queue.feasible(req.rows(), est_ns_per_token, live, dl)
                });
                if infeasible {
                    queue.reject_infeasible();
                } else if queue.will_reject_next() {
                    queue.reject_next();
                } else {
                    queue.offer(req);
                }
            }
            while next < trace.len() && trace[next].arrival_ns <= now {
                stats.offered += 1;
                let rows = trace[next].x.shape[0];
                let infeasible = self.cfg.deadline_ns.is_some_and(|dl| {
                    !queue.feasible(rows, est_ns_per_token, live, dl)
                });
                if infeasible {
                    // health-aware shed: at the current backlog and
                    // live-shard throughput this deadline cannot be met
                    queue.reject_infeasible();
                } else if queue.will_reject_next() {
                    // O(1) refusal: don't clone an activation tensor
                    // admission control would immediately discard
                    queue.reject_next();
                } else {
                    queue.offer(ServeRequest {
                        id: next,
                        arrival_ns: trace[next].arrival_ns,
                        x: trace[next].x.clone(),
                    });
                }
                next += 1;
            }
            if queue.is_empty() {
                // idle: jump to the next actionable instant (at least
                // one exists because the outer condition held, and both
                // candidates are strictly ahead of the current clock)
                let mut wake = u64::MAX;
                if next < trace.len() {
                    wake = trace[next].arrival_ns;
                }
                if let Some((due, _)) = retries.front() {
                    wake = wake.min(*due);
                }
                now = wake;
                continue;
            }
            // 2. dispatch decision
            let drained = next >= trace.len() && retries.is_empty();
            if !batcher.should_dispatch(&queue, now, drained) {
                // sleep the serve clock to the next actionable instant:
                // a drained trace with a non-empty queue always
                // dispatches above, so an arrival or a parked retry
                // exists here, and every candidate is strictly ahead of
                // `now` (due arrivals/retries were admitted, an expired
                // deadline dispatches)
                let mut wake = batcher
                    .deadline_ns(&queue)
                    .expect("non-empty queue has a deadline");
                if next < trace.len() {
                    wake = wake.min(trace[next].arrival_ns);
                }
                if let Some((due, _)) = retries.front() {
                    wake = wake.min(*due);
                }
                now = now.max(wake);
                continue;
            }
            // 3. one forward-only engine step over the coalesced batch
            let batch = batcher
                .form(&mut queue, d)
                .expect("dispatch decision implies a non-empty queue");
            let dispatched_at = now;
            let t0 = Instant::now();
            let (combined, step) = self.backend.execute_forward(&batch.x)?;
            let wall = t0.elapsed().as_nanos() as u64;
            now += wall;
            stats.record_batch(&step, batch.rows(), self.cfg.max_batch_tokens);
            let per_tok = wall as f64 / batch.rows().max(1) as f64;
            est_ns_per_token = if est_ns_per_token == 0.0 {
                per_tok
            } else {
                0.7 * est_ns_per_token + 0.3 * per_tok
            };
            // fault recovery degraded this batch iff any chunk was lost
            // (renormalized rows may sit on any replica of the batch,
            // so attribution is per-batch, not per-slot)
            let degraded =
                step.failed_chunks > 0 || step.degraded_tokens > 0;
            for slot in &batch.slots {
                if degraded && attempts[slot.id] < self.cfg.retry_max {
                    // re-offer after backoff; this attempt's output is
                    // discarded and latency keeps accruing from the
                    // original arrival
                    attempts[slot.id] += 1;
                    stats.retried += 1;
                    let rows = slot.rows.len();
                    let data = batch.x.data
                        [slot.rows.start * d..slot.rows.end * d]
                        .to_vec();
                    retries.push_back((
                        now + self.cfg.retry_backoff_ns,
                        ServeRequest {
                            id: slot.id,
                            arrival_ns: slot.arrival_ns,
                            x: TensorF::new(vec![rows, d], data),
                        },
                    ));
                    continue;
                }
                if self.cfg.capture_outputs {
                    let rows = slot.rows.len();
                    let data = combined.data
                        [slot.rows.start * d..slot.rows.end * d]
                        .to_vec();
                    outputs[slot.id] = Some(TensorF::new(vec![rows, d], data));
                }
                if degraded {
                    // out of retries: the renormalized output above is
                    // still delivered, but the request counts against
                    // the quality SLO, not as completed
                    stats.failed += 1;
                    continue;
                }
                stats.queue_wait.push(dispatched_at - slot.arrival_ns);
                stats.compute.push(wall);
                stats.total.push(now - slot.arrival_ns);
                if let Some(dl) = self.cfg.deadline_ns {
                    // delivered, but past its deadline: a latency-SLO
                    // violation, counted per completed request
                    if now - slot.arrival_ns > dl {
                        stats.slo_violations += 1;
                    }
                }
                stats.completed += 1;
                stats.tokens_served += slot.rows.len() as u64;
            }
        }
        stats.shed = queue.shed();
        stats.peak_queue_depth = queue.peak_depth();
        stats.wall_ns = now;
        Ok(ServeReport { stats, outputs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ExpertBackend;
    use crate::coordinator::ShardLayout;
    use crate::util::{prop, rng::Rng};

    fn mk_serve(
        d: usize,
        h: usize,
        n: usize,
        k: usize,
        devices: usize,
        cfg: ServeConfig,
        seed: u64,
    ) -> ServeLoop {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, d * h, 0.3),
                w_out: prop::vec_f32(&mut rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let sched = Scheduler::new(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
        );
        ServeLoop::new(sched, router, weights, cfg).unwrap()
    }

    fn burst(count: usize, rows: usize, d: usize, seed: u64) -> Vec<TimedRequest> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| TimedRequest {
                arrival_ns: 0,
                x: TensorF::new(
                    vec![rows, d],
                    prop::vec_f32(&mut rng, rows * d, 1.0),
                ),
            })
            .collect()
    }

    #[test]
    fn empty_trace_is_a_noop() {
        let serve = mk_serve(4, 6, 4, 2, 2, ServeConfig::default(), 1);
        let r = serve.run_trace(&[]).unwrap();
        assert_eq!(r.stats.completed, 0);
        assert_eq!(r.stats.shed, 0);
        assert_eq!(r.stats.batches, 0);
        assert!(r.outputs.is_empty());
    }

    #[test]
    fn simultaneous_arrivals_coalesce_into_one_batch() {
        let cfg = ServeConfig {
            queue_depth: 32,
            max_batch_tokens: 64,
            latency_budget_ns: u64::MAX / 2,
            capture_outputs: true,
            ..Default::default()
        };
        let serve = mk_serve(4, 6, 4, 2, 2, cfg, 2);
        let trace = burst(6, 3, 4, 7); // 18 tokens, fits one 64-token batch
        let r = serve.run_trace(&trace).unwrap();
        assert_eq!(r.stats.batches, 1, "drain should coalesce everything");
        assert_eq!(r.stats.completed, 6);
        assert_eq!(r.stats.tokens_served, 18);
        assert_eq!(r.stats.shed, 0);
        assert!((r.stats.batch_occupancy() - 18.0 / 64.0).abs() < 1e-9);
        assert!(r.outputs.iter().all(|o| o.is_some()));
        for o in r.outputs.iter().flatten() {
            assert_eq!(o.shape, vec![3, 4]);
        }
        // everyone rode the same batch, so queue wait is 0 on the serve
        // clock and total == compute
        assert_eq!(r.stats.queue_wait.max_ns(), 0);
        assert_eq!(
            r.stats.total.percentile(0.5),
            r.stats.compute.percentile(0.5)
        );
    }

    #[test]
    fn from_checkpoint_serves_exactly_the_trained_model() {
        use crate::runtime::ModelConfig;
        use crate::train::Trainer;

        // train a few streamed steps, freeze via save_streamed, then
        // serve the checkpoint and the in-memory state side by side
        let (d, h, n, k) = (6, 8, 4, 2);
        let model = ModelConfig::native_moe("serve-ckpt", d, n, k, h, 1, 8);
        let trainer = Trainer::native(model.clone());
        let mut state = trainer.init_streamed(7);
        let train_sched =
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng| {
            vec![TensorF::new(
                vec![10, d],
                prop::vec_f32(rng, 10 * d, 1.0),
            )]
        };
        let xs = mk(&mut rng);
        let targets = mk(&mut rng);
        for _ in 0..3 {
            trainer
                .step_streamed(&train_sched, &mut state, &xs, &targets, 0.05, None)
                .unwrap();
        }

        let dir = std::env::temp_dir().join("moe_serve_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.ckpt");
        checkpoint::save_streamed(&path, &model.name, &state).unwrap();

        let cfg = ServeConfig { capture_outputs: true, ..Default::default() };
        let from_ckpt = ServeLoop::from_checkpoint(
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
            &path,
            &model.name,
            &model,
            cfg.clone(),
        )
        .unwrap();
        let from_state = ServeLoop::from_state(
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
            state,
            cfg,
        )
        .unwrap();
        let trace = burst(4, 3, d, 9);
        let a = from_ckpt.run_trace(&trace).unwrap();
        let b = from_state.run_trace(&trace).unwrap();
        assert_eq!(a.stats.completed, 4);
        assert_eq!(a.stats.shed, 0);
        for (i, (x, y)) in a.outputs.iter().zip(b.outputs.iter()).enumerate() {
            let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
            assert_eq!(x.shape, y.shape);
            assert_eq!(
                x.data, y.data,
                "request {i}: checkpoint-served output drifted from the \
                 trained state"
            );
        }
    }

    #[test]
    fn rejects_malformed_traces() {
        let serve = mk_serve(4, 6, 4, 2, 1, ServeConfig::default(), 3);
        let bad_shape = vec![TimedRequest {
            arrival_ns: 0,
            x: TensorF::zeros(vec![2, 5]),
        }];
        assert!(serve.run_trace(&bad_shape).is_err());
        let empty_req = vec![TimedRequest {
            arrival_ns: 0,
            x: TensorF::zeros(vec![0, 4]),
        }];
        assert!(serve.run_trace(&empty_req).is_err());
        let unsorted = vec![
            TimedRequest { arrival_ns: 10, x: TensorF::zeros(vec![1, 4]) },
            TimedRequest { arrival_ns: 5, x: TensorF::zeros(vec![1, 4]) },
        ];
        assert!(serve.run_trace(&unsorted).is_err());
    }

    #[test]
    fn constructor_validates_dimensions() {
        let mut rng = Rng::new(4);
        let weights: Vec<ExpertWeights> = (0..3)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, 4 * 6, 0.3),
                w_out: prop::vec_f32(&mut rng, 6 * 4, 0.3),
                d_model: 4,
                hidden: 6,
            })
            .collect();
        // router says 4 experts, weights say 3
        let router = Router::flat_native(
            4, 4, 2,
            prop::vec_f32(&mut rng, 4 * 4, 0.5),
            None,
        );
        let sched = Scheduler::new(
            ShardLayout::new(1, 4),
            ExpertBackend::Native,
        );
        assert!(
            ServeLoop::new(sched, router, weights, ServeConfig::default())
                .is_err()
        );
    }
}
