//! Bounded request queue with admission control — the backpressure
//! boundary of the serving runtime.
//!
//! The queue is depth-bounded: memory stays O(depth) no matter how far
//! offered load exceeds engine throughput.  Over-limit admissions are
//! resolved by the [`AdmissionPolicy`] — reject the newcomer, or shed
//! the oldest queued request (the one whose latency SLO is already the
//! most blown).  Every drop is counted so
//! [`ServeStats::shed`](crate::serve::ServeStats) makes backpressure
//! observable instead of silent.

use std::collections::VecDeque;

use crate::runtime::TensorF;

/// Index of the request in the submitted trace (assigned by the
/// [`ServeLoop`](crate::serve::ServeLoop)).
pub type RequestId = usize;

/// One queued inference request: a ragged `(rows, d)` activation batch
/// plus its arrival stamp on the serve clock (nanoseconds).
pub struct ServeRequest {
    pub id: RequestId,
    pub arrival_ns: u64,
    pub x: TensorF,
}

impl ServeRequest {
    pub fn rows(&self) -> usize {
        self.x.shape[0]
    }
}

/// What to do when a request arrives at a full queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// refuse the newcomer (fail fast at the edge)
    Reject,
    /// admit the newcomer, dropping the longest-waiting request(s)
    ShedOldest,
}

/// FIFO of admitted requests, bounded at `max_depth` entries.
pub struct RequestQueue {
    max_depth: usize,
    policy: AdmissionPolicy,
    queue: VecDeque<ServeRequest>,
    /// running sum of queued rows — kept in lockstep with `queue` by
    /// `offer`/`pop` so `depth_tokens` is O(1) on the per-offer
    /// `feasible()` hot path instead of an O(depth) rescan
    queued_tokens: usize,
    offered: u64,
    shed: u64,
    peak_depth: usize,
}

impl RequestQueue {
    pub fn new(max_depth: usize, policy: AdmissionPolicy) -> Self {
        RequestQueue {
            max_depth: max_depth.max(1),
            policy,
            queue: VecDeque::new(),
            queued_tokens: 0,
            offered: 0,
            shed: 0,
            peak_depth: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total queued tokens (rows), the quantity the
    /// [`MicroBatcher`](crate::serve::MicroBatcher) fills batches from.
    /// O(1): a running count maintained by `offer`/`pop`/shed, since
    /// every `feasible()` call on the per-offer hot path reads it.
    pub fn depth_tokens(&self) -> usize {
        self.queued_tokens
    }

    /// Arrival stamp of the longest-waiting request.
    pub fn oldest_arrival_ns(&self) -> Option<u64> {
        self.queue.front().map(|r| r.arrival_ns)
    }

    /// Requests dropped by admission control so far.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Offer events seen by admission control so far — every
    /// [`offer`](Self::offer), [`reject_next`](Self::reject_next) and
    /// [`reject_infeasible`](Self::reject_infeasible) counts one, so
    /// `offered == admitted + shed` is checkable without a caller-side
    /// ledger.
    pub fn offered(&self) -> u64 {
        self.offered
    }

    /// High-water queue depth — the witness that memory stayed bounded.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    /// Whether the next [`offer`](Self::offer) would be refused
    /// outright (full queue under the reject policy) — lets callers
    /// skip materialising a request only to drop it, keeping rejection
    /// O(1) instead of O(rows · d) under overload.
    pub fn will_reject_next(&self) -> bool {
        matches!(self.policy, AdmissionPolicy::Reject)
            && self.queue.len() >= self.max_depth
    }

    /// Record the refusal of a request the caller never materialised
    /// (pairs with [`will_reject_next`](Self::will_reject_next)).
    pub fn reject_next(&mut self) {
        debug_assert!(self.will_reject_next());
        self.offered += 1;
        self.shed += 1;
    }

    /// Deadline feasibility under (possibly degraded) capacity: can a
    /// request of `rows` tokens, entering behind the current backlog,
    /// still finish within `deadline_ns` of arrival?  Throughput is
    /// `est_ns_per_token` scaled by `1 / live_fraction` — when fault
    /// recovery has masked shards out, the surviving shards serve the
    /// same token stream and the effective per-token cost rises
    /// proportionally.  `est_ns_per_token <= 0` (no measurement yet)
    /// is always feasible.
    pub fn feasible(
        &self,
        rows: usize,
        est_ns_per_token: f64,
        live_fraction: f64,
        deadline_ns: u64,
    ) -> bool {
        if est_ns_per_token <= 0.0 {
            return true;
        }
        let eff = est_ns_per_token / live_fraction.clamp(1e-9, 1.0);
        let wait = (self.depth_tokens() + rows) as f64 * eff;
        wait <= deadline_ns as f64
    }

    /// Record the up-front rejection of a request whose deadline is
    /// infeasible (pairs with [`feasible`](Self::feasible)); counts
    /// into the same [`shed`](Self::shed) total as admission-control
    /// drops so `offered == admitted + shed` stays a single invariant.
    pub fn reject_infeasible(&mut self) {
        self.offered += 1;
        self.shed += 1;
    }

    /// Offer a request.  Returns the requests admission control dropped:
    /// the newcomer under [`AdmissionPolicy::Reject`], the displaced
    /// oldest under [`AdmissionPolicy::ShedOldest`], empty when the
    /// queue had room.
    pub fn offer(&mut self, req: ServeRequest) -> Vec<ServeRequest> {
        self.offered += 1;
        let mut dropped = Vec::new();
        if self.queue.len() >= self.max_depth {
            match self.policy {
                AdmissionPolicy::Reject => {
                    self.shed += 1;
                    dropped.push(req);
                    return dropped;
                }
                AdmissionPolicy::ShedOldest => {
                    while self.queue.len() >= self.max_depth {
                        match self.queue.pop_front() {
                            Some(old) => {
                                self.shed += 1;
                                self.queued_tokens -= old.rows();
                                dropped.push(old);
                            }
                            None => break,
                        }
                    }
                    self.queued_tokens += req.rows();
                    self.queue.push_back(req);
                }
            }
        } else {
            self.queued_tokens += req.rows();
            self.queue.push_back(req);
        }
        self.peak_depth = self.peak_depth.max(self.queue.len());
        dropped
    }

    pub fn front(&self) -> Option<&ServeRequest> {
        self.queue.front()
    }

    pub fn pop(&mut self) -> Option<ServeRequest> {
        let req = self.queue.pop_front();
        if let Some(r) = &req {
            self.queued_tokens -= r.rows();
        }
        req
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival_ns: u64, rows: usize) -> ServeRequest {
        ServeRequest {
            id,
            arrival_ns,
            x: TensorF::zeros(vec![rows, 4]),
        }
    }

    #[test]
    fn fifo_order_and_token_depth() {
        let mut q = RequestQueue::new(8, AdmissionPolicy::Reject);
        assert!(q.offer(req(0, 10, 3)).is_empty());
        assert!(q.offer(req(1, 20, 5)).is_empty());
        assert_eq!(q.len(), 2);
        assert_eq!(q.depth_tokens(), 8);
        assert_eq!(q.oldest_arrival_ns(), Some(10));
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.pop().is_none());
        assert_eq!(q.shed(), 0);
    }

    #[test]
    fn reject_policy_drops_the_newcomer() {
        let mut q = RequestQueue::new(2, AdmissionPolicy::Reject);
        assert!(q.offer(req(0, 0, 1)).is_empty());
        assert!(q.offer(req(1, 1, 1)).is_empty());
        let dropped = q.offer(req(2, 2, 1));
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 2);
        assert_eq!(q.shed(), 1);
        assert_eq!(q.len(), 2);
        assert_eq!(q.front().unwrap().id, 0);
    }

    #[test]
    fn shed_oldest_policy_keeps_the_newcomer() {
        let mut q = RequestQueue::new(2, AdmissionPolicy::ShedOldest);
        q.offer(req(0, 0, 1));
        q.offer(req(1, 1, 1));
        let dropped = q.offer(req(2, 2, 1));
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 0);
        assert_eq!(q.shed(), 1);
        assert_eq!(q.len(), 2);
        assert_eq!(q.front().unwrap().id, 1);
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn cheap_rejection_matches_offer_accounting() {
        let mut q = RequestQueue::new(2, AdmissionPolicy::Reject);
        assert!(!q.will_reject_next());
        q.offer(req(0, 0, 1));
        q.offer(req(1, 1, 1));
        assert!(q.will_reject_next());
        q.reject_next();
        assert_eq!(q.shed(), 1);
        assert_eq!(q.len(), 2);
        // shed-oldest always admits the newcomer, so it never pre-rejects
        let mut s = RequestQueue::new(1, AdmissionPolicy::ShedOldest);
        s.offer(req(0, 0, 1));
        assert!(!s.will_reject_next());
    }

    #[test]
    fn deadline_feasibility_under_degraded_capacity() {
        let mut q = RequestQueue::new(16, AdmissionPolicy::Reject);
        q.offer(req(0, 0, 8)); // 8-token backlog
        // healthy: 10 tokens at 100ns/tok = 1000ns, inside a 2000ns SLO
        assert!(q.feasible(2, 100.0, 1.0, 2_000));
        // half the shards dead: effective cost doubles, SLO blown
        assert!(!q.feasible(2, 100.0, 0.5, 2_000));
        // no throughput estimate yet: always feasible
        assert!(q.feasible(2, 0.0, 0.5, 1));
        // zero live capacity clamps rather than dividing by zero
        assert!(!q.feasible(2, 100.0, 0.0, u64::MAX / 2));
        q.reject_infeasible();
        assert_eq!(q.shed(), 1);
        assert_eq!(q.len(), 1, "up-front rejection leaves the queue alone");
    }

    #[test]
    fn accounting_invariant_admitted_equals_popped_plus_shed_plus_queued() {
        // every offered request is exactly one of: popped, shed (by
        // admission control or infeasibility), or still queued
        let mut q = RequestQueue::new(4, AdmissionPolicy::Reject);
        let mut popped = 0u64;
        for i in 0..50 {
            // degrade live capacity over time; the deadline tightens
            let live = 1.0 - (i as f64 / 100.0);
            if !q.feasible(2, 50.0, live, 600) {
                q.reject_infeasible();
            } else if q.will_reject_next() {
                q.reject_next();
            } else {
                q.offer(req(i, i as u64, 2));
                if i % 3 == 0 && q.pop().is_some() {
                    popped += 1;
                }
            }
            // the queue's own ledger: every offer event (including the
            // unmaterialised rejections) is popped, shed, or queued
            assert_eq!(q.offered(), i as u64 + 1);
            assert_eq!(
                q.offered(),
                popped + q.shed() + q.len() as u64,
                "conservation broke at offer {i}"
            );
        }
        assert_eq!(q.offered(), 50);
        assert_eq!(q.offered(), popped + q.shed() + q.len() as u64);
        assert!(q.shed() > 0, "test never exercised a shed path");
        assert!(popped > 0);
    }

    #[test]
    fn cached_token_count_matches_recompute_across_interleavings() {
        // property test for the O(1) depth_tokens cache: across random
        // offer/pop/shed interleavings under both policies, the running
        // count always equals a from-scratch rescan of the queue
        let mut state = 0x9e37_79b9_u64;
        let mut rng = move || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        for policy in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
            let mut q = RequestQueue::new(5, policy);
            for i in 0..400 {
                match rng() % 4 {
                    // offers dominate so the queue fills and sheds
                    0 | 1 | 2 => {
                        q.offer(req(i, i as u64, 1 + rng() % 7));
                    }
                    _ => {
                        q.pop();
                    }
                }
                let recompute: usize =
                    q.queue.iter().map(|r| r.rows()).sum();
                assert_eq!(
                    q.depth_tokens(),
                    recompute,
                    "{policy:?} cache diverged at op {i}"
                );
            }
            assert!(q.shed() > 0, "interleaving never exercised a shed");
            // drain to empty: the cache must return to exactly zero
            while q.pop().is_some() {}
            assert_eq!(q.depth_tokens(), 0);
        }
    }

    #[test]
    fn depth_stays_bounded_under_sustained_overload() {
        for policy in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
            let mut q = RequestQueue::new(4, policy);
            for i in 0..100 {
                q.offer(req(i, i as u64, 2));
                assert!(q.len() <= 4, "{policy:?} queue overflowed");
            }
            assert_eq!(q.peak_depth(), 4);
            assert_eq!(q.shed(), 96);
        }
    }
}
