//! Pluggable serving backends: the engine-agnostic boundary between
//! admission control and model execution.
//!
//! [`ServeBackend`] is the lm-router-shaped seam — a named engine with
//! declared capabilities ([`BackendCaps`]: model dimension, batch
//! ceiling, numeric [`Precision`], checkpoint variant) and one
//! `execute_forward`-shaped entry point.  Admission control treats
//! capabilities as *hard filters*: a request that needs a capability a
//! backend lacks is never offered to it, no matter how idle it is —
//! filtering precedes scoring, so load balancing can only choose among
//! backends that could actually serve the request correctly.
//!
//! [`EngineBackend`] is the first implementation: one persistent
//! [`Scheduler`] engine over a frozen router + expert weights, serving
//! f32 bit-exactly or int8 within the kernel error budget — exactly
//! the execution core [`ServeLoop`](crate::serve::ServeLoop) always
//! had, now behind the trait so a fleet can mix checkpoints and
//! precisions (A/B serving, cheap-tier int8 + exact-tier f32) and the
//! multi-tenant front-end ([`crate::serve::TenantServeLoop`]) can
//! route per-request.

use anyhow::{bail, Result};

use crate::coordinator::scheduler::{ExpertWeights, StepStats};
use crate::coordinator::{Router, Scheduler};
use crate::kernels::quant::{Precision, QuantizedExpertWeights};
use crate::runtime::TensorF;

/// What a backend can serve — the hard-filter side of admission
/// (anything here that mismatches a request's requirements disqualifies
/// the backend before any load scoring happens).
#[derive(Clone, Debug)]
pub struct BackendCaps {
    /// model width every request's activations must match
    pub d_model: usize,
    /// engine batch ceiling (tokens); requests larger than this are
    /// hard-filtered rather than shipped as oversized solo batches
    pub max_batch_tokens: usize,
    /// numeric width this backend serves at
    pub precision: Precision,
    /// checkpoint / model-variant label requests can pin
    /// (e.g. `"base"` vs `"distilled"`)
    pub variant: String,
}

impl BackendCaps {
    /// Can this backend serve a `rows`-token request that requires
    /// `precision` / `variant` (either `None` = no requirement)?
    /// Pure capability check — no load or deadline terms.
    pub fn admits(
        &self,
        rows: usize,
        precision: Option<Precision>,
        variant: Option<&str>,
    ) -> bool {
        rows <= self.max_batch_tokens
            && precision.map_or(true, |p| p == self.precision)
            && variant.map_or(true, |v| v == self.variant)
    }
}

/// A named model-serving engine: capabilities plus one forward entry.
/// The serve loops own backends boxed, so heterogeneous fleets (mixed
/// checkpoints, mixed precisions, mock engines in tests) share one
/// dispatch path.
pub trait ServeBackend {
    fn name(&self) -> &str;

    fn caps(&self) -> &BackendCaps;

    /// Fraction of expert capacity currently alive (1.0 when no fault
    /// plan is active) — the throughput scale of deadline feasibility.
    fn live_fraction(&self) -> f64;

    /// One forward-only step over a coalesced `(rows, d_model)` batch.
    fn execute_forward(&self, x: &TensorF) -> Result<(TensorF, StepStats)>;

    /// Drain any engine trace spans recorded so far (empty unless the
    /// backend's engine has tracing enabled).
    fn take_spans(&self) -> Vec<crate::obs::Span> {
        Vec::new()
    }
}

/// The [`Scheduler`]-engine implementation of [`ServeBackend`]: a
/// frozen router + expert weights on one persistent engine, serving at
/// [`Precision::F32`] (bit-exact) or [`Precision::Int8`] (weight-only
/// quantized twins created at load; the f32 originals stay untouched).
pub struct EngineBackend {
    name: String,
    caps: BackendCaps,
    sched: Scheduler,
    router: Router,
    weights: Vec<ExpertWeights>,
    /// int8 twins of `weights` when `caps.precision` is `Int8`
    qweights: Option<Vec<QuantizedExpertWeights>>,
}

impl EngineBackend {
    /// Validate and freeze one engine.  Mirrors the checks the serve
    /// loop has always made: expert count consistent across router /
    /// weights / shard layout, uniform `d_model`, and int8 only on a
    /// natively-streaming configuration (fail at load, not mid-trace).
    pub fn new(
        name: &str,
        variant: &str,
        sched: Scheduler,
        router: Router,
        weights: Vec<ExpertWeights>,
        precision: Precision,
        max_batch_tokens: usize,
    ) -> Result<Self> {
        if weights.is_empty() {
            bail!("backend {name} needs at least one expert");
        }
        if router.n_experts != weights.len() {
            bail!(
                "backend {name}: router has {} experts but {} expert \
                 weights given",
                router.n_experts,
                weights.len()
            );
        }
        if sched.layout().n_experts != router.n_experts {
            bail!(
                "backend {name}: scheduler layout has {} experts but \
                 router has {}",
                sched.layout().n_experts,
                router.n_experts
            );
        }
        let d_model = router.d_model;
        for (e, w) in weights.iter().enumerate() {
            if w.d_model != d_model {
                bail!(
                    "backend {name}: expert {e} has d_model {} (router {})",
                    w.d_model,
                    d_model
                );
            }
        }
        let qweights = match precision {
            Precision::F32 => None,
            Precision::Int8 => {
                if !sched.streams_natively(&router) {
                    bail!(
                        "Precision::Int8 requires Native router + expert \
                         backends (streaming path); this configuration \
                         would silently serve f32"
                    );
                }
                Some(QuantizedExpertWeights::quantize_all(&weights))
            }
        };
        Ok(EngineBackend {
            name: name.to_string(),
            caps: BackendCaps {
                d_model,
                max_batch_tokens: max_batch_tokens.max(1),
                precision,
                variant: variant.to_string(),
            },
            sched,
            router,
            weights,
            qweights,
        })
    }

    /// The frozen f32 expert weights (always the checkpoint values —
    /// int8 serving quantizes a *copy* at load).
    pub fn weights(&self) -> &[ExpertWeights] {
        &self.weights
    }

    /// The int8 weight twins when serving at [`Precision::Int8`].
    pub fn quantized_weights(&self) -> Option<&[QuantizedExpertWeights]> {
        self.qweights.as_deref()
    }
}

impl ServeBackend for EngineBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn caps(&self) -> &BackendCaps {
        &self.caps
    }

    fn live_fraction(&self) -> f64 {
        self.sched.live_fraction()
    }

    fn execute_forward(&self, x: &TensorF) -> Result<(TensorF, StepStats)> {
        let (mut outs, step) = match &self.qweights {
            Some(q) => {
                self.sched.execute_forward_quant(&self.router, &[x], q)?
            }
            None => {
                self.sched.execute_forward(&self.router, &[x], &self.weights)?
            }
        };
        Ok((outs.remove(0), step))
    }

    fn take_spans(&self) -> Vec<crate::obs::Span> {
        self.sched.take_spans()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ExpertBackend;
    use crate::coordinator::ShardLayout;
    use crate::util::{prop, rng::Rng};

    fn mk_backend(name: &str, precision: Precision, seed: u64) -> EngineBackend {
        let (d, h, n, k) = (4, 6, 4, 2);
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, d * h, 0.3),
                w_out: prop::vec_f32(&mut rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let sched =
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        EngineBackend::new(name, "base", sched, router, weights, precision, 64)
            .unwrap()
    }

    #[test]
    fn caps_admit_is_a_pure_hard_filter() {
        let b = mk_backend("exact", Precision::F32, 1);
        let caps = b.caps();
        assert_eq!(caps.d_model, 4);
        assert!(caps.admits(64, None, None), "at the batch ceiling");
        assert!(!caps.admits(65, None, None), "over the batch ceiling");
        assert!(caps.admits(1, Some(Precision::F32), Some("base")));
        assert!(!caps.admits(1, Some(Precision::Int8), None));
        assert!(!caps.admits(1, None, Some("distilled")));
    }

    #[test]
    fn engine_backend_executes_deterministically() {
        let b = mk_backend("exact", Precision::F32, 2);
        let mut rng = Rng::new(9);
        let x = crate::runtime::TensorF::new(
            vec![3, 4],
            prop::vec_f32(&mut rng, 12, 1.0),
        );
        let (y1, s1) = b.execute_forward(&x).unwrap();
        let (y2, _) = b.execute_forward(&x).unwrap();
        assert_eq!(y1.shape, vec![3, 4]);
        assert_eq!(y1.data, y2.data, "same input must serve identical bits");
        assert_eq!(s1.failed_chunks, 0);
        assert_eq!(b.name(), "exact");
        assert!((b.live_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constructor_validates_like_the_serve_loop() {
        let (d, h, n) = (4, 6, 4);
        let mut rng = Rng::new(3);
        let weights: Vec<ExpertWeights> = (0..n - 1)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, d * h, 0.3),
                w_out: prop::vec_f32(&mut rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d, n, 2,
            prop::vec_f32(&mut rng, d * n, 0.5),
            None,
        );
        let sched =
            Scheduler::new(ShardLayout::new(1, n), ExpertBackend::Native);
        assert!(EngineBackend::new(
            "bad", "base", sched, router, weights, Precision::F32, 64
        )
        .is_err());
    }
}
