//! Serving runtime: continuous micro-batching inference on the
//! persistent execution engine.
//!
//! The paper's economics are a *serving* argument — sparse conditional
//! computation makes outrageous capacity affordable per query — and
//! this module is the path from "N concurrent requests of ragged
//! sizes" to MoE steps on the
//! [`ExecutionEngine`](crate::coordinator::ExecutionEngine).  Four
//! pieces:
//!
//! - [`RequestQueue`] (`queue.rs`) — bounded-depth admission control
//!   with a shed-oldest or reject policy: the backpressure boundary
//!   that keeps memory O(depth) at any offered load, counting every
//!   drop;
//! - [`MicroBatcher`] (`batcher.rs`) — coalesces queued requests into
//!   engine-sized token batches under a latency budget (dispatch when
//!   the batch fills *or* the oldest request's deadline slack runs
//!   out), carrying the row→request map that scatters combined outputs
//!   back to their owners;
//! - [`ServeLoop`] (`driver.rs`) — drives forward-only steps on
//!   [`Scheduler::execute_forward`](crate::coordinator::Scheduler::execute_forward)
//!   (gating frozen from a [`checkpoint`](crate::train::checkpoint)
//!   or a fresh init, no gate noise, no trainer bookkeeping), reusing
//!   the engine's pooled arenas step after step, on a hybrid serve
//!   clock: deterministic seeded arrivals, measured compute walls;
//! - [`ServeStats`] (`stats.rs`) — per-request queue/compute/total
//!   latency histograms (p50/p95/p99 order statistics), achieved
//!   tokens/sec, batch occupancy, and the admission ledger (`offered ==
//!   completed + shed + failed`, plus deadline/SLO violations among the
//!   completions).  Everything publishes into the unified
//!   [`crate::obs::Registry`] under `serve_*` keys; the one shared
//!   console line ([`ServeStats::summary_line`]) is a renderer over a
//!   registry snapshot ([`ServeStats::render_summary`]), the same
//!   snapshot `benches/serve.rs` exports to `BENCH_serve.json` and
//!   `repro trace` serialises as JSON/Prometheus text.
//!
//! Above the single-queue loop sits the multi-tenant front-end:
//!
//! - [`ServeBackend`] (`backend.rs`) — the engine-agnostic execution
//!   seam: a named backend with declared capabilities (`d_model`,
//!   batch ceiling, [`Precision`](crate::kernels::quant::Precision),
//!   checkpoint variant) and one `execute_forward` entry;
//!   [`EngineBackend`] wraps the [`Scheduler`](crate::coordinator::Scheduler)
//!   engine and is what [`ServeLoop`] executes through, so a fleet can
//!   mix checkpoints and precisions;
//! - [`TenantQueue`] / [`TenantServeLoop`] (`tenant.rs`) — per-tenant
//!   bounded lanes drained weighted-fair (deficit round-robin) or
//!   global-FIFO into the same [`MicroBatcher`] (via [`BatchSource`]),
//!   with capability-first admission (hard filters before load
//!   scoring) routing each request to a capable backend, and
//!   per-tenant [`ServeStats`] published as `serve_*{tenant="..."}`.
//!
//! The open-loop Poisson traffic generator lives in
//! [`crate::harness::workload`] (seeded, ragged request lengths,
//! bursty mode, multi-tenant heavy-hitter/long-tail mixes);
//! `examples/serve_demo.rs` and `repro serve` print
//! latency-vs-offered-load curves from it.  `rust/tests/serve.rs`
//! proves serve-path correctness differentially: scattered
//! [`ServeLoop`] outputs are bit-identical to running every request
//! alone through
//! [`Scheduler::execute_serial`](crate::coordinator::Scheduler::execute_serial),
//! and backpressure is asserted observable (bounded queue, counted
//! sheds) at offered loads above engine throughput.  `rust/tests/obs.rs`
//! proves the serve path is *bit-neutral under tracing*: the same trace
//! replayed with span recording on yields byte-identical outputs and
//! stats.  `rust/tests/tenants.rs` proves per-tenant conservation
//! (tenant ledgers sum to the global ledger), weighted-fair isolation
//! against a heavy hitter (with global FIFO as the violating
//! baseline), and that backend routing is bit-identical to serving
//! each request on its assigned backend alone.

pub mod backend;
pub mod batcher;
pub mod driver;
pub mod queue;
pub mod stats;
pub mod tenant;

pub use backend::{BackendCaps, EngineBackend, ServeBackend};
pub use batcher::{BatchSlot, BatchSource, MicroBatch, MicroBatcher};
pub use driver::{ServeConfig, ServeLoop, ServeReport, TimedRequest};
pub use queue::{AdmissionPolicy, RequestQueue, ServeRequest};
pub use stats::ServeStats;
pub use tenant::{
    DrainPolicy, LaneLedger, TenantQueue, TenantRequest, TenantServeConfig,
    TenantServeLoop, TenantServeReport, TenantSpec,
};
