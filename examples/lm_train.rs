//! End-to-end driver (DESIGN.md deliverable): train the ~100M-parameter
//! `e2e-100m` MoE language model (192 experts x 0.5M params + embeddings)
//! for a few hundred steps on the synthetic topic corpus, logging the loss
//! curve, balance telemetry, and held-out perplexity.  The run recorded in
//! EXPERIMENTS.md §End-to-end came from this binary.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example lm_train -- [steps] [config]
//! ```

use anyhow::Result;
use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::metrics::OpsModel;
use moe::runtime::{Engine, Manifest};
use moe::train::{checkpoint, Trainer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let cfg = args.get(1).cloned().unwrap_or_else(|| "e2e-100m".to_string());

    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    let trainer = Trainer::new(&engine, &manifest, &cfg)?;
    let c = trainer.entry.config.clone();
    let ops = OpsModel::from_config(&c);
    println!(
        "== {} ==\nparams: {:.1}M ({} experts x {}x{} + embed/softmax)\n\
         ops/timestep: {:.2}M  k={}  optimizer={}",
        cfg,
        trainer.entry.param_size as f64 / 1e6,
        c.n_experts,
        c.d_model,
        c.expert_hidden,
        c.ops_per_timestep as f64 / 1e6,
        c.k,
        c.optimizer,
    );

    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 64,
        branch: 4,
        mean_len: 12,
        seed: 0,
    });
    let mut train = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut test = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);

    let mut state = trainer.init(0)?;
    println!("initialized; training {steps} steps ({} tokens/step)",
             trainer.tokens_per_step);
    let t0 = std::time::Instant::now();
    let mut curve: Vec<(u64, f64)> = Vec::new();
    let metrics = trainer.run(&mut state, &mut train, steps, 10)?;
    for m in &metrics {
        curve.push((m.step, m.nll));
    }
    let wall = t0.elapsed().as_secs_f64();

    let eval = trainer.evaluate(&state, &mut test, 10)?;
    let tail = &metrics[metrics.len().saturating_sub(10)..];
    let nll_tail: f64 =
        tail.iter().map(|m| m.nll).sum::<f64>() / tail.len() as f64;
    println!("\n== loss curve (every 25 steps) ==");
    for (s, nll) in curve.iter().filter(|(s, _)| s % 25 == 0) {
        println!("step {s:>5}  train nll {nll:.4}  ppl {:.1}", nll.exp());
    }
    println!("\n== summary ==");
    println!("steps: {steps}  wall: {wall:.1}s  ({:.2}s/step, {:.0} tok/s)",
             wall / steps as f64,
             steps as f64 * trainer.tokens_per_step as f64 / wall);
    println!("train nll: {:.4} -> {:.4}", metrics[0].nll, nll_tail);
    println!("held-out perplexity: {:.2} (uniform would be {})",
             eval.perplexity(), c.vocab);
    println!("balance tail: CV^2(imp) {:.4}  CV^2(load) {:.4}  max/mean {:.2}  \
              dropped {:.3}",
             tail.iter().map(|m| m.cv_importance).sum::<f64>() / tail.len() as f64,
             tail.iter().map(|m| m.cv_load).sum::<f64>() / tail.len() as f64,
             tail.iter().map(|m| m.max_over_mean_load).sum::<f64>() / tail.len() as f64,
             tail.iter().map(|m| m.dropped_frac).sum::<f64>() / tail.len() as f64);
    println!("training FLOPs (paper accounting): {:.2e}",
             ops.train_flops(trainer.tokens_per_step * steps) as f64);

    let ckpt = std::path::PathBuf::from(format!("/tmp/{cfg}.ckpt"));
    checkpoint::save(&ckpt, &cfg, &state)?;
    println!("checkpoint: {}", ckpt.display());
    Ok(())
}
