//! Balance-loss ablation (paper Appendix A, Table 6): train the same MoE
//! with six (w_importance, w_load) combinations and report the balance
//! statistics.  The headline shape: no losses => expert collapse
//! (CV and max/mean blow up); either loss => balanced.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example balance_ablation -- [steps]
//! ```

use anyhow::Result;
use moe::harness::experiments::{run_lm_experiment, ExperimentOpts};
use moe::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(150);
    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    println!("== Table 6 ablation: losses vs expert balance ({steps} steps) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>10}",
        "w_imp / w_load", "test ppl", "CV(imp)", "CV(load)", "max/mean"
    );
    let opts = ExperimentOpts { steps, log_every: 0, ..Default::default() };
    for (wi, wl) in [("0.0", "0.0"), ("0.2", "0.0"), ("0.0", "0.2"),
                     ("0.1", "0.1"), ("0.01", "0.01"), ("1.0", "1.0")] {
        let cfg = format!("balance-wi{wi}-wl{wl}");
        let r = run_lm_experiment(&engine, &manifest, &cfg, &opts)?;
        println!(
            "{:<16} {:>10.2} {:>10.3} {:>10.3} {:>10.2}",
            format!("{wi} / {wl}"),
            r.test_perplexity,
            r.cv_importance.max(0.0).sqrt(),
            r.cv_load.max(0.0).sqrt(),
            r.max_over_mean_load
        );
    }
    println!("\npaper shape: the (0,0) row collapses (CV~3, max/mean ~18);");
    println!("every row with a loss stays balanced (CV<0.5, max/mean <1.5).");
    Ok(())
}
