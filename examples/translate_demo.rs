//! Translation demo (Tables 2-5 scenario): train the MoE seq2seq
//! (prefix-LM) on a synthetic language pair, then beam-decode a few
//! sentences and report BLEU vs the dense baseline.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example translate_demo -- [steps]
//! ```

use anyhow::Result;
use moe::data::synthetic::{CorpusSpec, TopicCorpus, BOS, EOS};
use moe::data::translation::{TranslationTask, SEP};
use moe::data::Vocab;
use moe::runtime::{Engine, Manifest};
use moe::translate::{bleu, BeamDecoder};
use moe::train::Trainer;
use moe::util::rng::Rng;

fn train_and_score(engine: &Engine, manifest: &Manifest, cfg: &str,
                   steps: u64, show: bool) -> Result<(f64, f64)> {
    let trainer = Trainer::new(engine, manifest, cfg)?;
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 8,
        branch: 3,
        mean_len: 7,
        seed: 100,
    });
    let task = TranslationTask::new(7, c.vocab);
    let mut state = trainer.init(0)?;
    let mut rng = Rng::new(42);
    for step in 0..steps {
        let batch = task.batch(&corpus, &mut rng, c.batch, c.seq_len);
        let m = trainer.step(&mut state, &batch)?;
        if show && step % 50 == 0 {
            eprintln!("[{cfg}] step {step:>4} nll {:.3}", m.nll);
        }
    }
    let mut erng = Rng::new(4242);
    let dev = vec![task.batch(&corpus, &mut erng, c.batch, c.seq_len)];
    let ppl = trainer.evaluate_tokens(&state, &dev)?.perplexity();

    let decoder = BeamDecoder::new(engine.load(manifest, cfg, "decode")?,
                                   &trainer.entry);
    let vocab = Vocab::synthetic(c.vocab);
    let seg = (c.seq_len + 1 - 3) / 2;
    let mut pairs = Vec::new();
    let mut drng = Rng::new(777);
    for i in 0..10 {
        let (src, tgt) = task.example(&corpus, &mut drng);
        let src = &src[..src.len().min(seg)];
        let tgt = &tgt[..tgt.len().min(seg)];
        let mut prefix = vec![BOS];
        prefix.extend_from_slice(src);
        prefix.push(SEP);
        let hyps = decoder.decode(&state.params, &prefix, 4, seg + 2, EOS)?;
        let mut hyp = hyps.first().map(|h| h.tokens.clone()).unwrap_or_default();
        hyp.retain(|&t| t != EOS);
        if show && i < 3 {
            println!("  src: {}", vocab.detokenize(src));
            println!("  ref: {}", vocab.detokenize(tgt));
            println!("  hyp: {}\n", vocab.detokenize(&hyp));
        }
        pairs.push((hyp, tgt.to_vec()));
    }
    Ok((ppl, bleu(&pairs)))
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);
    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    println!("== synthetic En->Xx translation, {steps} training steps ==\n");
    println!("-- MoE model (mt-moe: 64 experts, hierarchical, k=2) --");
    let (ppl_moe, bleu_moe) =
        train_and_score(&engine, &manifest, "mt-moe", steps, true)?;
    println!("-- dense baseline (mt-dense: matched ops/timestep) --");
    let (ppl_d, bleu_d) =
        train_and_score(&engine, &manifest, "mt-dense", steps, false)?;
    println!("\n{:<10} {:>10} {:>8}", "model", "dev ppl", "BLEU");
    println!("{:<10} {:>10.2} {:>8.2}", "mt-moe", ppl_moe, bleu_moe);
    println!("{:<10} {:>10.2} {:>8.2}", "mt-dense", ppl_d, bleu_d);
    Ok(())
}
