//! Serve demo: the latency-vs-offered-load curve of the continuous
//! micro-batching inference runtime (`moe::serve`), on a bare offline
//! checkout — no artifacts, no network.
//!
//! Calibrates the engine's serving capacity with a saturating burst,
//! then replays seeded open-loop Poisson traces at three offered loads
//! (0.3×, 1.0×, 3.0× capacity), printing p50/p99 latency, achieved
//! tokens/sec, batch occupancy and shed counts per point.  Above 1×
//! the queue saturates and admission control sheds — backpressure is
//! visible in the numbers, not in unbounded memory.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use moe::harness::workload::serve_load_curve;

fn main() -> anyhow::Result<()> {
    serve_load_curve(17, 4, &[0.3, 1.0, 3.0], 400)
}
