//! Quickstart: load the AOT artifacts, run a few training steps of a
//! small MoE language model, evaluate perplexity, and run a batch
//! through the streamed dependency-driven step executor
//! (`Scheduler::execute_streamed`), printing the per-phase breakdown
//! including the combine-overlap metric.
//!
//! ```bash
//! make artifacts                       # once: lower the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```
//!
//! # Serving
//!
//! The same engine serves inference traffic through the continuous
//! micro-batching runtime in `moe::serve`: a bounded `RequestQueue`
//! (reject / shed-oldest backpressure), a `MicroBatcher` that coalesces
//! ragged requests into engine-sized batches under a latency budget,
//! and a `ServeLoop` running forward-only steps
//! (`Scheduler::execute_forward`) with gating frozen from a
//! `checkpoint::save_streamed` checkpoint or a fresh init.  It needs no
//! artifacts — try the latency-vs-offered-load curve on a bare
//! checkout:
//!
//! ```bash
//! cargo run --release --example serve_demo
//! cargo run --release -- serve --devices 4      # same curve via repro
//! ```
//!
//! # Cluster scaling (`repro cluster`)
//!
//! The 64 → 4096-expert scaling study drives the *real* engine —
//! hierarchical O(√n) local-group routing, GShard-style capacity
//! buffers — and prices each step's measured dispatch plan on a
//! simulated multi-host topology (PCIe within a host, a slow fabric
//! between hosts).  It uses the corrected §3.2 traffic accounting:
//! `network_bytes` counts only routes whose expert lives on a
//! *different* device than the token's replica; a token dispatched to
//! an expert on its own shard never crosses the interconnect (those
//! bytes are reported separately as `local`).  Earlier revisions
//! charged every route, overstating the all-to-all:
//!
//! ```bash
//! cargo run --release -- cluster --rows 8
//! BENCH_SMOKE=1 cargo bench --bench cluster   # same study + BENCH_cluster.json
//! ```
//!
//! # Fault model & degraded mode (`repro chaos`)
//!
//! The streaming step optionally runs under a seeded, deterministic
//! `FaultPlan` (`moe::coordinator::faults`): per-chunk failures,
//! straggler delays past a deadline, dropped all-to-all combine
//! messages and permanent shard deaths are all pure keyed-hash draws —
//! same seed, same faults, bit-identical degraded outputs.  Recovery is
//! two-tier: a failed route first re-dispatches to the token's other
//! selected experts on live shards (`RecoveryPolicy::Redispatch`), and
//! whatever remains becomes lost gate mass — the combine then
//! *renormalizes* eq-1 over the surviving contributions, so outputs
//! stay finite under any schedule (even every shard dead).  Dead shards
//! are masked out of the router on subsequent steps, the serve loop
//! retries degraded requests with backoff and sheds infeasible
//! deadlines against `Scheduler::live_fraction`, and
//! `rust/tests/faults.rs` proves the degraded outputs bit-equal to a
//! serial failure-masked oracle:
//!
//! ```bash
//! cargo run --release -- chaos --rows 8       # rates × policies sweep
//! BENCH_SMOKE=1 cargo bench --bench chaos     # same sweep + BENCH_chaos.json
//! ```
//!
//! # Tracing & metrics (`repro trace`)
//!
//! The engine can record structured spans — route / gather / compute /
//! combine / retry per worker, tagged `(step, shard, expert, chunk,
//! replica)` — into lock-free per-worker rings (`moe::obs`), drained at
//! step end and exported as Chrome trace-event JSON that Perfetto
//! loads directly.  Tracing is off by default, costs one branch per
//! job when off, and is *bit-neutral* when on (§9 below asserts it).
//! All runtime telemetry — step phases, serve SLOs, fault and cluster
//! traffic counters — publishes into one typed metrics registry
//! (`moe::obs::Registry`); every console line above is a renderer over
//! a registry snapshot, and the same snapshot serialises as JSON or
//! Prometheus text:
//!
//! ```bash
//! cargo run --release -- trace --out trace.json   # spans + snapshot
//! MOE_TRACE=1 cargo run --release -- serve        # trace any command
//! BENCH_SMOKE=1 cargo bench --bench obs           # overhead < 5% gate
//! ```
//!
//! # Multi-tenant serving (`repro tenants`)
//!
//! In front of the serve loop sits a multi-tenant admission layer
//! (`moe::serve::tenant`): per-tenant bounded lanes drained into the
//! micro-batcher by deficit-round-robin weighted fair queueing (or a
//! global-FIFO baseline for contrast), capability-first admission
//! (batch ceiling, deadline feasibility, required precision / model
//! variant are hard filters *before* any load scoring), and routing
//! across several `ServeBackend` engines — e.g. an exact f32 fleet
//! next to an int8 canary.  Every tenant keeps a conserving admission
//! ledger (`offered == completed + shed + failed`) that sums exactly
//! to the global one, published under `serve_*{tenant="..."}` keys.
//! §10 below runs two tenants — one bursty flood, one small
//! interactive stream — through the weighted-fair drain; the full
//! isolation study (solo baseline vs WFQ vs FIFO under a 10× heavy
//! hitter) is:
//!
//! ```bash
//! cargo run --release -- tenants --devices 2      # isolation study
//! BENCH_SMOKE=1 cargo bench --bench tenants       # + BENCH_tenants.json
//! ```

use anyhow::Result;
use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::harness::distributed::{expert_weights, router_for};
use moe::harness::workload::{
    completed_fraction, phase_line, poisson_trace, trace_requests,
    TenantHarness, TraceSpec,
};
use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::kernels::quant::{Precision, SERVE_REL_ERR_BUDGET};
use moe::kernels::Kernel;
use moe::obs::{chrome_trace_json, ObsConfig, Registry};
use moe::runtime::{Engine, Manifest, ModelConfig, TensorF};
use moe::serve::{DrainPolicy, ServeConfig, ServeLoop, TenantSpec};
use moe::train::{StreamedStepOptions, Trainer};
use moe::util::rng::Rng;

fn main() -> Result<()> {
    // --- 1. load artifacts ---
    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // --- 2. train a 4-expert MoE LM for a handful of steps ---
    let cfg = "test-tiny";
    let trainer = Trainer::new(&engine, &manifest, cfg)?;
    let c = trainer.entry.config.clone();
    println!(
        "config {cfg}: {} experts, k={}, {} params",
        c.n_experts, c.k, trainer.entry.param_size
    );
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 4,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0)?;
    let metrics = trainer.run(&mut state, &mut batcher, 30, 10)?;
    println!(
        "loss: {:.3} -> {:.3} over {} steps",
        metrics.first().unwrap().loss,
        metrics.last().unwrap().loss,
        metrics.len()
    );

    // --- 3. held-out perplexity ---
    let mut test = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);
    let eval = trainer.evaluate(&state, &mut test, 10)?;
    println!("test perplexity: {:.2}", eval.perplexity());

    // --- 4. distributed MoE: the streamed step executor on 4 simulated
    //        devices (Native router + experts so routing, dispatch,
    //        expert compute and per-replica combine all pipeline) ---
    let entry = manifest.config(cfg)?.clone();
    let router = router_for(&entry, &state.params.data, &engine, &manifest,
                            false)?;
    let weights = expert_weights(&entry, &state.params.data)?;
    let sched = Scheduler::new(
        ShardLayout::new(4, c.n_experts),
        ExpertBackend::Native,
    );
    let mut rng = Rng::new(0);
    let xs: Vec<TensorF> = (0..2)
        .map(|_| {
            TensorF::new(
                vec![c.batch * c.seq_len, c.d_model],
                (0..c.batch * c.seq_len * c.d_model)
                    .map(|_| rng.normal_f32())
                    .collect(),
            )
        })
        .collect();
    let refs: Vec<&TensorF> = xs.iter().collect();
    let mut nrng = rng.fold_in(1);
    let s = sched.execute_streamed(&router, &refs, &weights, Some(&mut nrng))?;
    println!(
        "streamed MoE: {} routes over {} experts, busiest shard {} tokens, \
         output shape {:?}",
        s.plan.total_routes(),
        c.n_experts,
        s.stats.busiest_shard_tokens,
        s.outs[0].shape
    );
    // the one shared phase-report formatter (harness::workload)
    println!("  phases: {}", phase_line(&s.stats));

    // --- 5. trainable gating on the native path: a few artifact-free
    //        streamed steps with the eq-6/eq-8 balance losses learning
    //        the gating network (Adam), balance-CV trajectory printed ---
    let nat = Trainer::native(ModelConfig::native_moe(
        "quickstart-native", 16, 8, 2, 32, 2, 32,
    ));
    let mut nstate = nat.init_streamed(7);
    let nsched = Scheduler::new(ShardLayout::new(2, 8), ExpertBackend::Native);
    let mut drng = Rng::new(9);
    let mk = |rng: &mut Rng, s: f32| -> Vec<TensorF> {
        (0..2)
            .map(|_| {
                TensorF::new(
                    vec![32, 16],
                    (0..32 * 16).map(|_| rng.normal_f32() * s).collect(),
                )
            })
            .collect()
    };
    let nxs = mk(&mut drng, 1.0);
    let ntargets = mk(&mut drng, 0.5);
    let mut noise_rng = drng.fold_in(2);
    let opts = StreamedStepOptions {
        lr: 0.01,
        train_gating: true,
        w_importance: 0.1,
        w_load: 0.1,
    };
    println!("native gating training (balance losses on):");
    for i in 0..12 {
        let m = nat.step_streamed_with(
            &nsched,
            &mut nstate,
            &nxs,
            &ntargets,
            Some(&mut noise_rng),
            &opts,
        )?;
        if i % 3 == 0 || i == 11 {
            println!(
                "  step {:>2}: loss {:.4} balance {:.4} CV(imp) {:.3} \
                 CV(load) {:.3}",
                i, m.loss, m.balance_loss, m.cv_importance, m.cv_load
            );
        }
    }
    // --- 6. one rung of the cluster scaling study: real engine step,
    //        priced on the simulated multi-host topology with the
    //        corrected network-bytes accounting (local routes free;
    //        `repro cluster` sweeps the full 64 → 4096 ladder) ---
    let sim = moe::harness::cluster_sim::ClusterSim::build(64, 4, Some(1.0), 7)?;
    let p = sim.point()?;
    println!("cluster rung: {}", moe::harness::cluster_sim::point_line(&p));

    // --- 7. fault model & degraded mode: one chaos point — seeded
    //        chunk failures + a recovery policy on the real engine and
    //        serve loop, asserting liveness and request conservation
    //        (`repro chaos` sweeps rates × policies + shard deaths) ---
    let plan = moe::coordinator::FaultPlan {
        chunk_fail_rate: 0.2,
        combine_drop_rate: 0.05,
        ..moe::coordinator::FaultPlan::none(21)
    };
    let chaos = moe::harness::chaos::ChaosSim::build(2, 8, 8, plan, 21)?;
    let cp = moe::harness::chaos::run_point(&chaos, 2, 16)?;
    println!("chaos point: {}", moe::harness::chaos::point_line(&cp));
    assert!(cp.conserved() && cp.all_finite);

    // --- 8. kernels & quantized serving: every hot-path GEMM routes
    //        through one selected SIMD kernel (MOE_KERNEL=scalar pins
    //        the retained bit-exact oracle), and serving can run the
    //        experts int8 weight-only — quantized at load, f32
    //        checkpoints untouched, error budgeted against the f32
    //        path over the same weights ---
    println!(
        "matmul kernel: {} (MOE_KERNEL overrides; scalar = bit-exact \
         oracle)",
        Kernel::selected_name()
    );
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 33,
            rate_per_sec: 30_000.0,
            n_requests: 16,
            min_rows: 1,
            max_rows: 5,
            bursty: false,
        }),
        c.d_model,
        35,
    );
    let run_precision = |precision| -> Result<Vec<Option<TensorF>>> {
        let serve = ServeLoop::new(
            Scheduler::new(
                ShardLayout::new(2, c.n_experts),
                ExpertBackend::Native,
            ),
            router_for(&entry, &state.params.data, &engine, &manifest, false)?,
            weights.clone(),
            ServeConfig {
                queue_depth: 64,
                max_batch_tokens: 16,
                latency_budget_ns: 200_000,
                capture_outputs: true,
                precision,
                ..Default::default()
            },
        )?;
        Ok(serve.run_trace(&trace)?.outputs)
    };
    let y32 = run_precision(Precision::F32)?;
    let y8 = run_precision(Precision::Int8)?;
    let mut worst = 0f64;
    for (a, b) in y32.iter().zip(y8.iter()) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        let norm: f64 =
            a.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let err: f64 = a
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| (*x as f64 - *y as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        if norm > 1e-9 {
            worst = worst.max(err / norm);
        }
    }
    println!(
        "int8 serving: {} requests, worst normwise rel err {:.2e} \
         (budget {SERVE_REL_ERR_BUDGET})",
        trace.len(),
        worst
    );
    assert!(worst < SERVE_REL_ERR_BUDGET);

    // --- 9. tracing & metrics: the §4 model again, once untraced and
    //        once with span recording on.  Tracing is bit-neutral (it
    //        only reads clocks), so the outputs must match bit for bit;
    //        the recorded worker timelines export as a Chrome trace for
    //        Perfetto and the stats publish into the unified registry
    //        the console lines above are rendered from ---
    let traced = Scheduler::new(
        ShardLayout::new(4, c.n_experts),
        ExpertBackend::Native,
    )
    .with_obs(ObsConfig::enabled());
    let mut a_rng = Rng::new(77).fold_in(1);
    let plain = sched.execute_streamed(&router, &refs, &weights,
                                       Some(&mut a_rng))?;
    let mut b_rng = Rng::new(77).fold_in(1);
    let spanned = traced.execute_streamed(&router, &refs, &weights,
                                          Some(&mut b_rng))?;
    for (a, b) in plain.outs.iter().zip(spanned.outs.iter()) {
        assert_eq!(a.data, b.data, "tracing must not perturb outputs");
    }
    let spans = traced.take_spans();
    assert!(!spans.is_empty(), "traced step must record spans");
    let trace_path = "quickstart_trace.json";
    std::fs::write(trace_path, chrome_trace_json(&spans, 4))?;
    let mut reg = Registry::new();
    spanned.stats.publish(&mut reg);
    println!(
        "tracing: {} spans -> {trace_path} (bit-identical outputs; open in \
         chrome://tracing or https://ui.perfetto.dev; `repro trace` writes \
         a fuller one)",
        spans.len()
    );
    println!("registry: {}", reg.snapshot().to_json().trim_end());

    // --- 10. multi-tenant serving: the weighted-fair admission
    //         front-end.  Two tenants share one engine — "batch"
    //         floods a burst into a bounded lane while "interactive"
    //         holds a small smooth stream at 4x the scheduling weight
    //         and a deadline.  The DRR drain keeps the interactive
    //         lane served by weight while the flood absorbs the
    //         shedding; every tenant's admission ledger conserves and
    //         sums exactly to the global one (`repro tenants` runs the
    //         full solo / weighted-fair / global-FIFO isolation study
    //         against a 10x heavy hitter) ---
    let th = TenantHarness::new(41, 2);
    let tlp = th.single_loop(
        vec![
            TenantSpec::new("batch", 8),
            TenantSpec {
                weight: 4,
                deadline_ns: Some(5_000_000),
                ..TenantSpec::new("interactive", 8)
            },
        ],
        th.config(DrainPolicy::WeightedFair),
    )?;
    let ttrace = th.trace(&[
        TraceSpec {
            seed: 41,
            rate_per_sec: 1e8, // the burst: everything lands at once
            n_requests: 48,
            min_rows: th.min_rows,
            max_rows: th.max_rows,
            bursty: true,
        },
        TraceSpec {
            seed: 43,
            rate_per_sec: 50_000.0,
            n_requests: 12,
            min_rows: 1,
            max_rows: 4,
            bursty: false,
        },
    ]);
    let trep = tlp.run_trace(&ttrace)?;
    println!("multi-tenant serving (weighted-fair drain):");
    for line in trep.summary_lines() {
        println!("  {line}");
    }
    let g = &trep.global;
    assert_eq!(g.offered, g.completed + g.shed + g.failed);
    assert_eq!(g.offered, ttrace.len() as u64);
    let (batch, inter) = (&trep.per_tenant[0], &trep.per_tenant[1]);
    assert_eq!(
        g.offered,
        batch.offered + inter.offered,
        "per-tenant ledgers must sum to the global one"
    );
    println!(
        "  fairness: interactive completed {:.0}% vs batch {:.0}% — the \
         burst sheds, the weighted stream serves",
        100.0 * completed_fraction(inter),
        100.0 * completed_fraction(batch),
    );

    println!("quickstart OK");
    Ok(())
}
