//! Quickstart: load the AOT artifacts, run a few training steps of a
//! small MoE language model, evaluate perplexity, and route a batch
//! through the distributed coordinator.
//!
//! ```bash
//! make artifacts                       # once: lower the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use moe::coordinator::Dispatcher;
use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::harness::distributed::{expert_weights, router_for};
use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::runtime::{Engine, Manifest, TensorF};
use moe::train::Trainer;
use moe::util::rng::Rng;

fn main() -> Result<()> {
    // --- 1. load artifacts ---
    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // --- 2. train a 4-expert MoE LM for a handful of steps ---
    let cfg = "test-tiny";
    let trainer = Trainer::new(&engine, &manifest, cfg)?;
    let c = trainer.entry.config.clone();
    println!(
        "config {cfg}: {} experts, k={}, {} params",
        c.n_experts, c.k, trainer.entry.param_size
    );
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 4,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0)?;
    let metrics = trainer.run(&mut state, &mut batcher, 30, 10)?;
    println!(
        "loss: {:.3} -> {:.3} over {} steps",
        metrics.first().unwrap().loss,
        metrics.last().unwrap().loss,
        metrics.len()
    );

    // --- 3. held-out perplexity ---
    let mut test = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);
    let eval = trainer.evaluate(&state, &mut test, 10)?;
    println!("test perplexity: {:.2}", eval.perplexity());

    // --- 4. distributed routing: 4 simulated devices, expert shards ---
    let entry = manifest.config(cfg)?.clone();
    let router = router_for(&entry, &state.params.data, &engine, &manifest,
                            true)?;
    let weights = expert_weights(&entry, &state.params.data)?;
    let sched = Scheduler::new(
        ShardLayout::new(4, c.n_experts),
        ExpertBackend::Artifact {
            exe: engine.load(&manifest, cfg, "expert")?,
            capacity: c.capacity,
        },
    );
    let mut rng = Rng::new(0);
    let x = TensorF::new(
        vec![c.batch * c.seq_len, c.d_model],
        (0..c.batch * c.seq_len * c.d_model).map(|_| rng.normal_f32()).collect(),
    );
    let mut nrng = rng.fold_in(1);
    let dec = router.route(&x, Some(&mut nrng))?;
    let plan = Dispatcher::plan(std::slice::from_ref(&dec), c.n_experts);
    let (outs, stats) = sched.execute(&plan, &[&x], &weights)?;
    println!(
        "distributed MoE: {} routes over {} experts, busiest shard {} \
         tokens, output shape {:?}",
        plan.total_routes(),
        c.n_experts,
        stats.busiest_shard_tokens,
        outs[0].shape
    );
    println!("quickstart OK");
    Ok(())
}
